//! Per-attempt span timelines: recording, persistence, and analysis.
//!
//! Every task attempt walks the state machine
//!
//! ```text
//! queued ──→ dispatched ──→ exec_start ──→ exec_end ──→ recorded
//!    └─────→ restored  ─────────────────────────────────→ recorded
//! ```
//!
//! Each transition is one [`SpanEvent`] carrying a microsecond
//! timestamp relative to the run's trace epoch. Recording goes through
//! a [`Tracer`]: events land in per-thread striped buffers (the same
//! zero-contention layout as `metrics::Timer`) and a sink thread
//! drains them to an append-only trace file ([`TRACE_FILE`]) encoded
//! with the storage codec — binary by default, one JSON object per
//! line under `WireFormat::Json`, auto-detected record-by-record on
//! read so mixed files stay readable.
//!
//! # Clock anchoring
//!
//! All timestamps come from one process-wide monotonic clock
//! ([`monotonic_us`]). The tracer notes the wall-clock epoch
//! (`wall_epoch_us`, UNIX microseconds) in the file header so separate
//! runs can be placed on a calendar axis. Remote workers report
//! `exec_start`/`exec_end` on *their* monotonic clocks; the supervisor
//! maps those onto its own clock with a per-worker offset estimated at
//! the `Ready` exchange before calling [`Tracer::record_mono`], so the
//! persisted timeline is always on the coordinator's axis.

use crate::util::codec::{self, WireFormat};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::fs::OpenOptions;
use std::io::{self, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// File name of the span log inside a trace directory. The name keeps
/// the `.jsonl` suffix even for binary content (matching the cache and
/// checkpoint stores, whose `.json` files hold tagged binary by
/// default); readers auto-detect the encoding per record.
pub const TRACE_FILE: &str = "trace.jsonl";

/// Schema tag carried by the header record at the top of a trace file.
pub const TRACE_SCHEMA: &str = "memento.trace/v1";

/// Schema tag of the footer record appended when a tracer finishes; it
/// carries the written-span and dropped-span counts so a reader can
/// prove the file is complete.
pub const TRACE_END_SCHEMA: &str = "memento.trace.end/v1";

/// Number of independently locked span buffers (matches the reservoir
/// striping in `metrics.rs`).
const TRACE_STRIPES: usize = 16;

/// How often the sink thread drains the stripes to disk.
const FLUSH_INTERVAL: Duration = Duration::from_millis(25);

/// Microseconds since the process-wide monotonic epoch (the first call
/// in this process). Cheap, thread-safe, and never goes backwards —
/// every local span timestamp and clock-offset estimate is derived
/// from this single axis.
pub fn monotonic_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = *EPOCH.get_or_init(Instant::now);
    Instant::now().duration_since(epoch).as_micros() as u64
}

/// A small stable identifier for the calling thread, assigned on first
/// use. The thread backend uses it as the span `worker` id so per-
/// worker utilization is meaningful without plumbing pool indices
/// through the job closure.
pub fn thread_worker_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    thread_local! {
        static ID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    ID.with(|id| *id)
}

/// One state in the per-attempt span timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanState {
    /// The attempt is waiting for a worker (entered the dispatch queue).
    Queued,
    /// The attempt was handed to a worker (task frame written, or the
    /// thread-backend job invoked).
    Dispatched,
    /// The attempt was satisfied from a checkpoint or cache restore and
    /// never executed.
    Restored,
    /// The experiment function started executing (worker-side clock on
    /// remote backends, mapped to the coordinator's axis).
    ExecStart,
    /// The experiment function returned or panicked.
    ExecEnd,
    /// The terminal outcome was recorded by the coordinator.
    Recorded,
}

impl SpanState {
    /// All states, in timeline order.
    pub const ALL: [SpanState; 6] = [
        SpanState::Queued,
        SpanState::Dispatched,
        SpanState::Restored,
        SpanState::ExecStart,
        SpanState::ExecEnd,
        SpanState::Recorded,
    ];

    /// The wire/storage name of this state.
    pub fn as_str(self) -> &'static str {
        match self {
            SpanState::Queued => "queued",
            SpanState::Dispatched => "dispatched",
            SpanState::Restored => "restored",
            SpanState::ExecStart => "exec_start",
            SpanState::ExecEnd => "exec_end",
            SpanState::Recorded => "recorded",
        }
    }

    /// Parses a wire/storage name back into a state.
    pub fn parse(s: &str) -> Option<SpanState> {
        SpanState::ALL.iter().copied().find(|st| st.as_str() == s)
    }
}

/// One recorded state transition for one task attempt.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanEvent {
    /// The task's position in the expansion order (`TaskSpec::index`).
    pub index: u64,
    /// Attempt number (1-based for executed attempts, 0 for restores).
    pub attempt: u32,
    /// Which transition this event records.
    pub state: SpanState,
    /// Microseconds since the trace epoch, on the coordinator's
    /// monotonic axis.
    pub t_us: u64,
    /// Worker that owned the attempt at this transition, when known
    /// (supervisor slot id, or [`thread_worker_id`] on threads).
    pub worker: Option<u64>,
    /// Optional human label (the task's `k=v` parameter string; set on
    /// the `queued`/`restored` event only, to keep the file small).
    pub label: Option<String>,
}

impl SpanEvent {
    /// Serializes the event as a flat JSON object.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("index", Json::int(self.index as i64)),
            ("attempt", Json::int(self.attempt as i64)),
            ("state", Json::str(self.state.as_str())),
            ("t_us", Json::int(self.t_us as i64)),
        ];
        if let Some(w) = self.worker {
            fields.push(("worker", Json::int(w as i64)));
        }
        if let Some(l) = &self.label {
            fields.push(("label", Json::str(l.clone())));
        }
        Json::obj(fields)
    }

    /// Parses an event from its JSON form; `None` when a required
    /// field is missing or malformed.
    pub fn from_json(doc: &Json) -> Option<SpanEvent> {
        Some(SpanEvent {
            index: doc.get("index")?.as_i64()? as u64,
            attempt: doc.get("attempt")?.as_i64()? as u32,
            state: SpanState::parse(doc.get("state")?.as_str()?)?,
            t_us: doc.get("t_us")?.as_i64()? as u64,
            worker: doc.get("worker").and_then(Json::as_i64).map(|w| w as u64),
            label: doc.get("label").and_then(Json::as_str).map(str::to_string),
        })
    }
}

/// The header record at the top of a trace file.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceHeader {
    /// Schema tag ([`TRACE_SCHEMA`]).
    pub schema: String,
    /// UNIX microseconds corresponding to trace-relative `t_us == 0`.
    pub wall_epoch_us: u64,
}

/// Counts returned by [`Tracer::finish`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceStats {
    /// Span events written to the file by this tracer.
    pub spans: u64,
    /// Span events dropped (recorded after the sink had closed).
    pub dropped: u64,
}

struct TraceShared {
    stripes: Vec<Mutex<Vec<SpanEvent>>>,
    dropped: AtomicU64,
    closed: AtomicBool,
}

/// Records span events with near-zero contention and streams them to
/// an append-only trace file via a background sink thread.
///
/// Create one per run with [`Tracer::create`]; call [`Tracer::finish`]
/// (or drop it) to flush the stripes, append the footer record, and
/// join the sink. Recording after `finish` increments the dropped
/// counter instead of blocking.
pub struct Tracer {
    epoch_mono_us: u64,
    shared: Arc<TraceShared>,
    sink: Mutex<Option<JoinHandle<io::Result<u64>>>>,
    path: PathBuf,
}

impl Tracer {
    /// Opens (append-create) `dir/trace.jsonl`, writes a header record
    /// anchoring the trace epoch to the wall clock, and starts the
    /// sink thread. `format` selects the record encoding.
    pub fn create(dir: &Path, format: WireFormat) -> io::Result<Tracer> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(TRACE_FILE);
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        let mut writer = BufWriter::new(file);

        let epoch_mono_us = monotonic_us();
        let wall_epoch_us = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        let header = Json::obj(vec![
            ("schema", Json::str(TRACE_SCHEMA)),
            ("wall_epoch_us", Json::int(wall_epoch_us as i64)),
        ]);
        write_record(&mut writer, &header, format)?;
        writer.flush()?;

        let shared = Arc::new(TraceShared {
            stripes: (0..TRACE_STRIPES).map(|_| Mutex::new(Vec::new())).collect(),
            dropped: AtomicU64::new(0),
            closed: AtomicBool::new(false),
        });
        let sink_shared = Arc::clone(&shared);
        let sink = std::thread::Builder::new()
            .name("memento-trace-sink".into())
            .spawn(move || sink_loop(sink_shared, writer, format))?;

        Ok(Tracer {
            epoch_mono_us,
            shared,
            sink: Mutex::new(Some(sink)),
            path,
        })
    }

    /// Path of the trace file this tracer appends to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Microseconds since this tracer's epoch, on the local monotonic
    /// clock.
    pub fn now_us(&self) -> u64 {
        monotonic_us().saturating_sub(self.epoch_mono_us)
    }

    /// Records a transition stamped with the current time.
    pub fn record(
        &self,
        index: usize,
        attempt: u32,
        state: SpanState,
        worker: Option<u64>,
        label: Option<String>,
    ) {
        let t_us = self.now_us();
        self.push(SpanEvent {
            index: index as u64,
            attempt,
            state,
            t_us,
            worker,
            label,
        });
    }

    /// Records a transition at an explicit timestamp on the local
    /// monotonic axis (as returned by [`monotonic_us`]). Used for
    /// worker-reported exec timestamps after clock-offset mapping.
    pub fn record_mono(
        &self,
        index: usize,
        attempt: u32,
        state: SpanState,
        mono_us: u64,
        worker: Option<u64>,
    ) {
        self.push(SpanEvent {
            index: index as u64,
            attempt,
            state,
            t_us: mono_us.saturating_sub(self.epoch_mono_us),
            worker,
            label: None,
        });
    }

    /// Span events dropped so far (only possible after `finish`).
    pub fn dropped(&self) -> u64 {
        self.shared.dropped.load(Ordering::Relaxed)
    }

    /// Stops the sink thread, flushing all buffered spans and
    /// appending the footer record. Idempotent: a second call returns
    /// `spans: 0`.
    pub fn finish(&self) -> io::Result<TraceStats> {
        self.shared.closed.store(true, Ordering::SeqCst);
        let handle = self.sink.lock().unwrap_or_else(|e| e.into_inner()).take();
        let spans = match handle {
            Some(h) => h.join().map_err(|_| io::Error::other("trace sink thread panicked"))??,
            None => 0,
        };
        Ok(TraceStats {
            spans,
            dropped: self.shared.dropped.load(Ordering::Relaxed),
        })
    }

    fn push(&self, event: SpanEvent) {
        if self.shared.closed.load(Ordering::Relaxed) {
            self.shared.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let stripe = thread_worker_id() as usize % TRACE_STRIPES;
        let mut buf = self.shared.stripes[stripe]
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        buf.push(event);
    }
}

impl Drop for Tracer {
    fn drop(&mut self) {
        let _ = self.finish();
    }
}

fn write_record(
    writer: &mut BufWriter<std::fs::File>,
    doc: &Json,
    format: WireFormat,
) -> io::Result<()> {
    match format {
        WireFormat::Binary => writer.write_all(&codec::encode(doc)),
        WireFormat::Json => {
            writer.write_all(doc.to_string().as_bytes())?;
            writer.write_all(b"\n")
        }
    }
}

fn sink_loop(
    shared: Arc<TraceShared>,
    mut writer: BufWriter<std::fs::File>,
    format: WireFormat,
) -> io::Result<u64> {
    let mut written: u64 = 0;
    loop {
        let closing = shared.closed.load(Ordering::SeqCst);
        for stripe in &shared.stripes {
            let drained = {
                let mut buf = stripe.lock().unwrap_or_else(|e| e.into_inner());
                std::mem::take(&mut *buf)
            };
            for event in &drained {
                write_record(&mut writer, &event.to_json(), format)?;
                written += 1;
            }
        }
        if closing {
            let footer = Json::obj(vec![
                ("schema", Json::str(TRACE_END_SCHEMA)),
                ("spans", Json::int(written as i64)),
                ("dropped", Json::int(shared.dropped.load(Ordering::Relaxed) as i64)),
            ]);
            write_record(&mut writer, &footer, format)?;
            writer.flush()?;
            return Ok(written);
        }
        std::thread::sleep(FLUSH_INTERVAL);
    }
}

// ---- reading ------------------------------------------------------------

/// A parsed trace file: header, span events in file order, and footer
/// counts when the run finished cleanly.
#[derive(Debug, Clone, Default)]
pub struct TraceFile {
    /// Header of the (first) tracer session in the file.
    pub header: Option<TraceHeader>,
    /// All span events, in the order the sink wrote them.
    pub spans: Vec<SpanEvent>,
    /// Sum of footer `spans` counts; `None` when no footer was found
    /// (the run is still live, or died before `finish`).
    pub footer_spans: Option<u64>,
    /// Sum of footer `dropped` counts.
    pub dropped: Option<u64>,
}

/// Reads and parses a trace file, auto-detecting binary vs JSON per
/// record. Resumed runs append a fresh header/footer pair; all spans
/// are merged and footer counts summed.
pub fn read_trace(path: &Path) -> io::Result<TraceFile> {
    let bytes = std::fs::read(path)?;
    parse_trace(&bytes).map_err(io::Error::other)
}

/// Parses raw trace-file bytes; see [`read_trace`].
pub fn parse_trace(bytes: &[u8]) -> Result<TraceFile, String> {
    let mut out = TraceFile::default();
    let mut pos = 0usize;
    while pos < bytes.len() {
        match bytes[pos] {
            b'\n' | b'\r' | b' ' | b'\t' => {
                pos += 1;
                continue;
            }
            b if b == codec::BINARY_MAGIC => {
                pos += 1;
                let doc = codec::read_value(bytes, &mut pos, 0).map_err(|e| e.to_string())?;
                classify(&doc, &mut out)?;
            }
            _ => {
                let end = bytes[pos..]
                    .iter()
                    .position(|&c| c == b'\n')
                    .map(|o| pos + o)
                    .unwrap_or(bytes.len());
                let line = std::str::from_utf8(&bytes[pos..end])
                    .map_err(|e| format!("trace file is not UTF-8 at byte {pos}: {e}"))?;
                pos = end;
                let doc = json::parse(line.trim()).map_err(|e| format!("trace record: {e}"))?;
                classify(&doc, &mut out)?;
            }
        }
    }
    Ok(out)
}

fn classify(doc: &Json, out: &mut TraceFile) -> Result<(), String> {
    if let Some(schema) = doc.get("schema").and_then(Json::as_str) {
        if schema == TRACE_SCHEMA {
            let wall = doc.get("wall_epoch_us").and_then(Json::as_i64).unwrap_or(0) as u64;
            if out.header.is_none() {
                out.header = Some(TraceHeader {
                    schema: schema.to_string(),
                    wall_epoch_us: wall,
                });
            }
        } else if schema == TRACE_END_SCHEMA {
            let spans = doc.get("spans").and_then(Json::as_i64).unwrap_or(0) as u64;
            let dropped = doc.get("dropped").and_then(Json::as_i64).unwrap_or(0) as u64;
            out.footer_spans = Some(out.footer_spans.unwrap_or(0) + spans);
            out.dropped = Some(out.dropped.unwrap_or(0) + dropped);
        } else {
            return Err(format!("unknown trace record schema: {schema}"));
        }
        return Ok(());
    }
    match SpanEvent::from_json(doc) {
        Some(ev) => {
            out.spans.push(ev);
            Ok(())
        }
        None => Err(format!("malformed span record: {doc}")),
    }
}

// ---- analysis -----------------------------------------------------------

/// p50/p95 of one timeline phase across all attempts that have it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseStats {
    /// Median duration of the phase, microseconds.
    pub p50_us: u64,
    /// 95th-percentile duration, microseconds.
    pub p95_us: u64,
    /// Number of attempts contributing samples.
    pub samples: usize,
}

/// Per-worker activity derived from exec spans.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WorkerUtil {
    /// Worker id (supervisor slot or thread-backend id).
    pub worker: u64,
    /// Attempts whose exec window ran on this worker.
    pub completed: u64,
    /// Total microseconds spent inside exec windows.
    pub busy_us: u64,
    /// `busy_us` over the whole trace span, in `[0, 1]`.
    pub utilization: f64,
}

/// One attempt highlighted by the analysis (straggler or critical
/// path).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Straggler {
    /// Expansion index of the task.
    pub index: u64,
    /// Attempt number.
    pub attempt: u32,
    /// Exec-window duration, microseconds.
    pub exec_us: u64,
    /// The task's parameter label when the trace carried one.
    pub label: Option<String>,
}

/// Aggregate view of a trace produced by [`summarize`].
#[derive(Debug, Clone, Default)]
pub struct TraceSummary {
    /// Distinct `(index, attempt)` pairs in the trace.
    pub attempts: usize,
    /// Executed attempts carrying the full five-state sequence.
    pub complete: usize,
    /// Attempts satisfied by restore instead of execution.
    pub restored: usize,
    /// Whole-trace span (first to last event), microseconds.
    pub span_us: u64,
    /// `queued → dispatched` wait.
    pub queue_wait: PhaseStats,
    /// `dispatched → exec_start` latency (frame + pickup).
    pub dispatch_lag: PhaseStats,
    /// `exec_start → exec_end` (the experiment function itself).
    pub exec: PhaseStats,
    /// `exec_end → recorded` latency (result return + bookkeeping).
    pub record_lag: PhaseStats,
    /// Per-worker utilization, sorted by worker id.
    pub workers: Vec<WorkerUtil>,
    /// The attempt whose `recorded` timestamp is latest — the tail the
    /// run waited on.
    pub critical_path: Option<Straggler>,
    /// Top attempts by exec duration, longest first.
    pub stragglers: Vec<Straggler>,
}

#[derive(Default, Clone)]
struct AttemptTimeline {
    queued: Option<u64>,
    dispatched: Option<u64>,
    restored: Option<u64>,
    exec_start: Option<u64>,
    exec_end: Option<u64>,
    recorded: Option<u64>,
    worker: Option<u64>,
    label: Option<String>,
}

fn group_timelines(spans: &[SpanEvent]) -> BTreeMap<(u64, u32), AttemptTimeline> {
    let mut map: BTreeMap<(u64, u32), AttemptTimeline> = BTreeMap::new();
    for ev in spans {
        let tl = map.entry((ev.index, ev.attempt)).or_default();
        let slot = match ev.state {
            SpanState::Queued => &mut tl.queued,
            SpanState::Dispatched => &mut tl.dispatched,
            SpanState::Restored => &mut tl.restored,
            SpanState::ExecStart => &mut tl.exec_start,
            SpanState::ExecEnd => &mut tl.exec_end,
            SpanState::Recorded => &mut tl.recorded,
        };
        if slot.is_none() {
            *slot = Some(ev.t_us);
        }
        if tl.worker.is_none() {
            tl.worker = ev.worker;
        }
        if tl.label.is_none() {
            tl.label = ev.label.clone();
        }
    }
    map
}

fn percentile_us(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn phase_stats(mut samples: Vec<u64>) -> PhaseStats {
    samples.sort_unstable();
    PhaseStats {
        p50_us: percentile_us(&samples, 0.50),
        p95_us: percentile_us(&samples, 0.95),
        samples: samples.len(),
    }
}

/// Builds a [`TraceSummary`] from raw span events, keeping the
/// `top_k` longest exec windows as stragglers.
pub fn summarize(spans: &[SpanEvent], top_k: usize) -> TraceSummary {
    let timelines = group_timelines(spans);
    let mut summary = TraceSummary {
        attempts: timelines.len(),
        ..TraceSummary::default()
    };
    if spans.is_empty() {
        return summary;
    }
    let t_min = spans.iter().map(|e| e.t_us).min().unwrap_or(0);
    let t_max = spans.iter().map(|e| e.t_us).max().unwrap_or(0);
    summary.span_us = t_max.saturating_sub(t_min);

    let mut queue_wait = Vec::new();
    let mut dispatch_lag = Vec::new();
    let mut exec = Vec::new();
    let mut record_lag = Vec::new();
    let mut workers: BTreeMap<u64, (u64, u64)> = BTreeMap::new();
    let mut execs: Vec<Straggler> = Vec::new();

    for ((index, attempt), tl) in &timelines {
        if tl.restored.is_some() {
            summary.restored += 1;
        }
        if let (Some(q), Some(d)) = (tl.queued, tl.dispatched) {
            queue_wait.push(d.saturating_sub(q));
        }
        if let (Some(d), Some(s)) = (tl.dispatched, tl.exec_start) {
            dispatch_lag.push(s.saturating_sub(d));
        }
        if let (Some(e), Some(r)) = (tl.exec_end, tl.recorded) {
            record_lag.push(r.saturating_sub(e));
        }
        if let (Some(s), Some(e)) = (tl.exec_start, tl.exec_end) {
            let dur = e.saturating_sub(s);
            exec.push(dur);
            let w = workers.entry(tl.worker.unwrap_or(0)).or_insert((0, 0));
            w.0 += 1;
            w.1 += dur;
            execs.push(Straggler {
                index: *index,
                attempt: *attempt,
                exec_us: dur,
                label: tl.label.clone(),
            });
            if tl.queued.is_some() && tl.dispatched.is_some() && tl.recorded.is_some() {
                summary.complete += 1;
            }
        }
    }

    summary.queue_wait = phase_stats(queue_wait);
    summary.dispatch_lag = phase_stats(dispatch_lag);
    summary.exec = phase_stats(exec);
    summary.record_lag = phase_stats(record_lag);

    let span = summary.span_us.max(1) as f64;
    summary.workers = workers
        .into_iter()
        .map(|(worker, (completed, busy_us))| WorkerUtil {
            worker,
            completed,
            busy_us,
            utilization: busy_us as f64 / span,
        })
        .collect();

    summary.critical_path = timelines
        .iter()
        .filter_map(|((i, a), tl)| tl.recorded.map(|r| (r, *i, *a, tl)))
        .max_by_key(|(r, ..)| *r)
        .map(|(_, index, attempt, tl)| Straggler {
            index,
            attempt,
            exec_us: match (tl.exec_start, tl.exec_end) {
                (Some(s), Some(e)) => e.saturating_sub(s),
                _ => 0,
            },
            label: tl.label.clone(),
        });

    execs.sort_by(|a, b| b.exec_us.cmp(&a.exec_us));
    execs.truncate(top_k);
    summary.stragglers = execs;
    summary
}

fn fmt_us(us: u64) -> String {
    if us < 1_000 {
        format!("{us}us")
    } else if us < 1_000_000 {
        format!("{:.1}ms", us as f64 / 1_000.0)
    } else {
        format!("{:.3}s", us as f64 / 1_000_000.0)
    }
}

impl TraceSummary {
    /// Renders the summary as the multi-line text block printed by
    /// `memento trace summarize` and `memento status`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace: {} attempts ({} complete, {} restored) over {}\n",
            self.attempts,
            self.complete,
            self.restored,
            fmt_us(self.span_us)
        ));
        for (name, ph) in [
            ("queue wait  ", &self.queue_wait),
            ("dispatch lag", &self.dispatch_lag),
            ("exec        ", &self.exec),
            ("record lag  ", &self.record_lag),
        ] {
            out.push_str(&format!(
                "  {name}  p50 {:>8}  p95 {:>8}  ({} samples)\n",
                fmt_us(ph.p50_us),
                fmt_us(ph.p95_us),
                ph.samples
            ));
        }
        out.push_str(&format!("  workers: {}\n", self.workers.len()));
        for w in &self.workers {
            out.push_str(&format!(
                "    worker {:>3}: {} tasks, busy {:>5.1}% ({})\n",
                w.worker,
                w.completed,
                w.utilization * 100.0,
                fmt_us(w.busy_us)
            ));
        }
        if let Some(cp) = &self.critical_path {
            out.push_str(&format!(
                "  critical path: task {} attempt {} (exec {}{})\n",
                cp.index,
                cp.attempt,
                fmt_us(cp.exec_us),
                cp.label
                    .as_deref()
                    .map(|l| format!(", {l}"))
                    .unwrap_or_default()
            ));
        }
        if !self.stragglers.is_empty() {
            out.push_str("  stragglers:\n");
            for s in &self.stragglers {
                out.push_str(&format!(
                    "    task {} attempt {}: exec {}{}\n",
                    s.index,
                    s.attempt,
                    fmt_us(s.exec_us),
                    s.label
                        .as_deref()
                        .map(|l| format!(" [{l}]"))
                        .unwrap_or_default()
                ));
            }
        }
        out
    }
}

/// Converts a trace into Chrome trace-event JSON (the
/// `{"traceEvents": [...]}` form Perfetto and `chrome://tracing`
/// load). Each attempt contributes a `queue` slice (queued →
/// exec start) and an `exec` slice (the experiment function), placed
/// on the worker's track.
pub fn chrome_trace(header: Option<&TraceHeader>, spans: &[SpanEvent]) -> Json {
    let timelines = group_timelines(spans);
    let mut events = Vec::new();
    for ((index, attempt), tl) in &timelines {
        let tid = Json::int(tl.worker.unwrap_or(0) as i64);
        let name = tl.label.clone().unwrap_or_else(|| format!("task {index}"));
        let args = Json::obj(vec![
            ("index", Json::int(*index as i64)),
            ("attempt", Json::int(*attempt as i64)),
        ]);
        if let (Some(q), Some(s)) = (tl.queued, tl.exec_start.or(tl.dispatched)) {
            if s > q {
                events.push(Json::obj(vec![
                    ("name", Json::str(format!("{name} (wait)"))),
                    ("cat", Json::str("queue")),
                    ("ph", Json::str("X")),
                    ("ts", Json::int(q as i64)),
                    ("dur", Json::int((s - q) as i64)),
                    ("pid", Json::int(0)),
                    ("tid", tid.clone()),
                    ("args", args.clone()),
                ]));
            }
        }
        if let (Some(s), Some(e)) = (tl.exec_start, tl.exec_end) {
            events.push(Json::obj(vec![
                ("name", Json::str(name.clone())),
                ("cat", Json::str("exec")),
                ("ph", Json::str("X")),
                ("ts", Json::int(s as i64)),
                ("dur", Json::int(e.saturating_sub(s) as i64)),
                ("pid", Json::int(0)),
                ("tid", tid),
                ("args", args),
            ]));
        }
    }
    let mut fields = vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::arr(events)),
    ];
    if let Some(h) = header {
        fields.push((
            "metadata",
            Json::obj(vec![
                ("schema", Json::str(h.schema.clone())),
                ("wall_epoch_us", Json::int(h.wall_epoch_us as i64)),
            ]),
        ));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;

    fn ev(index: u64, attempt: u32, state: SpanState, t_us: u64) -> SpanEvent {
        SpanEvent {
            index,
            attempt,
            state,
            t_us,
            worker: Some(index % 2),
            label: (state == SpanState::Queued).then(|| format!("k={index}")),
        }
    }

    #[test]
    fn span_event_json_roundtrip_both_formats() {
        let original = SpanEvent {
            index: 42,
            attempt: 3,
            state: SpanState::ExecStart,
            t_us: 123_456_789,
            worker: Some(7),
            label: Some("lr=0.1,model=svc".to_string()),
        };
        for format in [WireFormat::Json, WireFormat::Binary] {
            let bytes = codec::write_document(&original.to_json(), format);
            let doc = codec::read_document(&bytes).expect("decode");
            let back = SpanEvent::from_json(&doc).expect("parse");
            assert_eq!(back, original);
        }
    }

    #[test]
    fn span_event_json_tolerates_missing_optionals() {
        let doc = json::parse(r#"{"index":1,"attempt":1,"state":"queued","t_us":10}"#).unwrap();
        let ev = SpanEvent::from_json(&doc).expect("parse");
        assert_eq!(ev.worker, None);
        assert_eq!(ev.label, None);
    }

    #[test]
    fn tracer_writes_readable_file_in_both_formats() {
        for format in [WireFormat::Binary, WireFormat::Json] {
            let dir = TempDir::new("trace").expect("tempdir");
            let tracer = Tracer::create(dir.path(), format).expect("create");
            for i in 0..10usize {
                tracer.record(i, 1, SpanState::Queued, None, Some(format!("k={i}")));
                tracer.record(i, 1, SpanState::Dispatched, Some(0), None);
                tracer.record(i, 1, SpanState::ExecStart, Some(0), None);
                tracer.record(i, 1, SpanState::ExecEnd, Some(0), None);
                tracer.record(i, 1, SpanState::Recorded, None, None);
            }
            let stats = tracer.finish().expect("finish");
            assert_eq!(stats.spans, 50);
            assert_eq!(stats.dropped, 0);

            let parsed = read_trace(&dir.path().join(TRACE_FILE)).expect("read");
            assert_eq!(parsed.spans.len(), 50);
            assert_eq!(parsed.footer_spans, Some(50));
            assert_eq!(parsed.dropped, Some(0));
            let header = parsed.header.expect("header");
            assert_eq!(header.schema, TRACE_SCHEMA);
            assert!(header.wall_epoch_us > 0);
        }
    }

    #[test]
    fn tracer_counts_drops_after_finish() {
        let dir = TempDir::new("trace-drop").expect("tempdir");
        let tracer = Tracer::create(dir.path(), WireFormat::Binary).expect("create");
        tracer.finish().expect("finish");
        tracer.record(0, 1, SpanState::Queued, None, None);
        assert_eq!(tracer.dropped(), 1);
    }

    #[test]
    fn tracer_records_across_threads_without_loss() {
        let dir = TempDir::new("trace-mt").expect("tempdir");
        let tracer = Arc::new(Tracer::create(dir.path(), WireFormat::Binary).expect("create"));
        let mut handles = Vec::new();
        for t in 0..8usize {
            let tr = Arc::clone(&tracer);
            handles.push(std::thread::spawn(move || {
                for i in 0..100usize {
                    tr.record(t * 100 + i, 1, SpanState::Recorded, None, None);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = tracer.finish().expect("finish");
        assert_eq!(stats.spans, 800);
        assert_eq!(stats.dropped, 0);
        let parsed = read_trace(&dir.path().join(TRACE_FILE)).expect("read");
        assert_eq!(parsed.spans.len(), 800);
    }

    #[test]
    fn summarize_reports_phases_workers_and_stragglers() {
        let mut spans = Vec::new();
        for i in 0..4u64 {
            let base = i * 1_000;
            spans.push(ev(i, 1, SpanState::Queued, base));
            spans.push(ev(i, 1, SpanState::Dispatched, base + 100));
            spans.push(ev(i, 1, SpanState::ExecStart, base + 150));
            spans.push(ev(i, 1, SpanState::ExecEnd, base + 150 + (i + 1) * 200));
            spans.push(ev(i, 1, SpanState::Recorded, base + 150 + (i + 1) * 200 + 50));
        }
        let s = summarize(&spans, 2);
        assert_eq!(s.attempts, 4);
        assert_eq!(s.complete, 4);
        assert_eq!(s.restored, 0);
        assert_eq!(s.queue_wait.samples, 4);
        assert_eq!(s.queue_wait.p50_us, 100);
        assert_eq!(s.exec.samples, 4);
        assert_eq!(s.workers.len(), 2);
        assert_eq!(s.stragglers.len(), 2);
        assert_eq!(s.stragglers[0].index, 3);
        assert_eq!(s.stragglers[0].exec_us, 800);
        let cp = s.critical_path.expect("critical path");
        assert_eq!(cp.index, 3);
        assert!(!s.render().is_empty());
    }

    #[test]
    fn chrome_export_emits_complete_events() {
        let spans = vec![
            ev(0, 1, SpanState::Queued, 0),
            ev(0, 1, SpanState::Dispatched, 10),
            ev(0, 1, SpanState::ExecStart, 20),
            ev(0, 1, SpanState::ExecEnd, 120),
            ev(0, 1, SpanState::Recorded, 130),
        ];
        let header = TraceHeader {
            schema: TRACE_SCHEMA.to_string(),
            wall_epoch_us: 1_700_000_000_000_000,
        };
        let doc = chrome_trace(Some(&header), &spans);
        let events = doc.get("traceEvents").and_then(|j| match j {
            Json::Arr(items) => Some(items),
            _ => None,
        });
        let events = events.expect("traceEvents array");
        assert_eq!(events.len(), 2);
        for e in events {
            assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
            assert!(e.get("dur").and_then(Json::as_i64).unwrap_or(-1) >= 0);
        }
        assert!(doc.get("metadata").is_some());
    }
}
