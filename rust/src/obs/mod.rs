//! Observability: span tracing and live fleet telemetry.
//!
//! This module is the run-inspection spine of the crate. It has two
//! halves, deliberately decoupled from the execution backends so the
//! same types describe a thread-pool run and a multi-machine fleet:
//!
//! - [`trace`] — per-attempt span timelines. Every task attempt walks
//!   the state machine `queued → restored|dispatched → exec_start →
//!   exec_end → recorded`; each transition is a [`trace::SpanEvent`]
//!   with a monotonic microsecond timestamp anchored to one wall-clock
//!   epoch per run. Events are recorded into striped buffers (the same
//!   zero-contention pattern as `metrics::Timer`) and flushed by a sink
//!   thread to an append-only trace file in the PR 6 codec (binary by
//!   default, auto-detected on read).
//! - [`snapshot`] — [`snapshot::MetricsSnapshot`], a serializable
//!   point-in-time capture of `RunMetrics` counters/percentiles plus
//!   fleet state (queue depth, per-worker completions, heartbeat age,
//!   crash-budget remaining, windowed observed rate). Snapshots ride in
//!   `RunEvent::Telemetry`, in the final `RunSummary`, and on disk as
//!   `metrics.snap` for `memento status`.
//!
//! On the process and TCP-remote backends, worker-side execution
//! timestamps travel back in `Outcome` frames (protocol v4) on the
//! worker's own monotonic clock; the supervisor maps them onto its
//! clock using a per-worker offset estimated at the `Ready` exchange,
//! so a single merged timeline spans process and machine boundaries.
//!
//! Tracing is **off by default** — a run pays nothing unless a trace
//! directory is configured.

pub mod snapshot;
pub mod trace;

pub use snapshot::{FleetStats, MetricsSnapshot, WorkerStat};
pub use trace::{SpanEvent, SpanState, TraceSummary, Tracer};
