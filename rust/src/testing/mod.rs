//! Test-support utilities, including the property-testing mini-framework.

pub mod prop;
