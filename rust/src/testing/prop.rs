//! A miniature property-based testing framework (offline `proptest`
//! replacement).
//!
//! Coordinator invariants (expansion counts, hash stability, scheduler
//! exactly-once execution, cache idempotence, resume semantics) are tested
//! with randomized inputs. The framework is deliberately small:
//!
//! - [`Gen`] wraps a seeded [`Rng`](crate::util::rng::Rng) with combinators
//!   for sizes, vectors, strings, and choices;
//! - [`check`] runs a property over `n` seeded cases and, on failure,
//!   reports the *seed* so the case can be replayed deterministically
//!   (`MEMENTO_PROP_SEED=<seed>` reruns a single case);
//! - no shrinking — cases are kept small instead, which in practice
//!   localizes failures well enough for this codebase.

use crate::util::rng::Rng;

/// Random-input generator handed to properties.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    /// A generator seeded deterministically.
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed) }
    }

    /// Access the underlying RNG for custom draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Uniform usize in `[lo, hi]` (inclusive; the common case for sizes).
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi + 1)
    }

    /// A uniform `u64`.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// A uniform `f64` in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    /// A biased coin flip (`true` with probability `p`).
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Vector of `len` items from `f`.
    pub fn vec_of<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Short ASCII identifier (for parameter names etc.).
    pub fn ident(&mut self, max_len: usize) -> String {
        let len = self.size(1, max_len.max(1));
        (0..len)
            .map(|_| {
                let alphabet = b"abcdefghijklmnopqrstuvwxyz_0123456789";
                alphabet[self.rng.below(alphabet.len())] as char
            })
            .collect()
    }

    /// Uniformly chosen element of a slice (cloned).
    pub fn pick<T: Clone>(&mut self, xs: &[T]) -> T {
        xs[self.rng.below(xs.len())].clone()
    }
}

/// Outcome of a single property case.
pub type PropResult = Result<(), String>;

/// Convenience: fail a property with a formatted message.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Runs `property` over `cases` seeded inputs; panics (test failure) on the
/// first failing case, printing the failing seed for replay.
///
/// Setting `MEMENTO_PROP_SEED` replays exactly one case with that seed.
pub fn check(name: &str, cases: u64, property: impl Fn(&mut Gen) -> PropResult) {
    if let Ok(seed_str) = std::env::var("MEMENTO_PROP_SEED") {
        let seed: u64 = seed_str.parse().expect("MEMENTO_PROP_SEED must be a u64");
        let mut g = Gen::new(seed);
        if let Err(msg) = property(&mut g) {
            panic!("property '{name}' failed on replay seed {seed}: {msg}");
        }
        return;
    }
    // Derive per-case seeds from the property name so distinct properties
    // explore distinct corners even with the same case indices.
    let name_salt: u64 = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for case in 0..cases {
        let seed = name_salt.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = property(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed}): {msg}\n\
                 replay with MEMENTO_PROP_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        // interior mutability via a cell to count invocations
        let counter = std::cell::Cell::new(0u64);
        check("always-true", 25, |g| {
            counter.set(counter.get() + 1);
            let n = g.size(0, 10);
            prop_assert!(n <= 10, "size out of bounds: {n}");
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "replay with MEMENTO_PROP_SEED=")]
    fn failing_property_reports_seed() {
        check("always-false", 5, |_| Err("nope".into()));
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(42);
        let mut b = Gen::new(42);
        for _ in 0..50 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn ident_is_wellformed() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let id = g.ident(8);
            assert!(!id.is_empty() && id.len() <= 8);
            assert!(id.chars().all(|c| c.is_ascii_lowercase()
                || c.is_ascii_digit()
                || c == '_'));
        }
    }

    #[test]
    fn vec_of_has_len() {
        let mut g = Gen::new(2);
        let v = g.vec_of(7, |g| g.size(0, 3));
        assert_eq!(v.len(), 7);
    }
}
