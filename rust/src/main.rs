//! `memento` — the CLI leader process.
//!
//! Subcommands:
//!   expand  <config.json>              show the task expansion (E1)
//!   run     <config.json> [opts]       run registered experiments over a matrix
//!   resume  <config.json> [opts]       resume a checkpointed run
//!   exps                               list the experiments this binary registers
//!   serve   --connect host:port ...    standing worker for a remote run
//!   daemon  --root <dir> [opts]        multi-tenant run-submission service
//!   submit  <config.json> [opts]       submit a grid to a running daemon
//!   attach  <run-id> [opts]            re-attach to a daemon run's event stream
//!   status  --checkpoint <dir>         inspect a run manifest/telemetry
//!           --daemon <addr>            ... or a daemon's live status document
//!   report  --results <file> [opts]    pivot saved results into a table
//!   trace   <summarize|export> <dir>   analyze a recorded span trace
//!   query   <store-dir> [opts]         search results across runs in a store
//!   migrate <legacy-dir> <store-dir>   fold per-run JSON dirs into a store
//!
//! Experiments come from the built-in registry (`experiments::registry`):
//! the §3 `grid` (parameters `dataset`/`feature_engineering`/
//! `preprocessing`/`model`; the AOT MLP model family is available whenever
//! `artifacts/` exists — `make artifacts`) and the `echo` smoke workload.
//! `grid` doubles as the unnamed fallback, so a plain `memento run` keeps
//! producing pre-registry task ids; `--exp NAME` or a reserved `exp` row
//! parameter selects other entries per run or per task.

use memento::config::loader;
use memento::coordinator::checkpoint::CheckpointStore;
use memento::coordinator::expand;
use memento::coordinator::memento::Memento;
use memento::coordinator::notify::ConsoleNotificationProvider;
use memento::coordinator::results::ResultSet;
use memento::coordinator::run::RunEvent;
use memento::experiments::registry::Registry;
use memento::runtime::artifact::shared_store;
use memento::util::cli::{CliError, CliSpec};
use memento::util::json::{parse, Json};
use std::path::Path;
use std::process::ExitCode;
use std::time::Duration;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("{}", top_help());
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "expand" => cmd_expand(rest),
        "run" => cmd_run(rest, false),
        "resume" => cmd_run(rest, true),
        "exps" => cmd_exps(rest),
        "serve" => cmd_serve(rest),
        "daemon" => cmd_daemon(rest),
        "submit" => cmd_submit(rest),
        "attach" => cmd_attach(rest),
        "status" => cmd_status(rest),
        "report" => cmd_report(rest),
        "trace" => cmd_trace(rest),
        "query" => cmd_query(rest),
        "migrate" => cmd_migrate(rest),
        // Hidden: the worker half of `--isolation process`. Spawned by the
        // supervisor with MEMENTO_WORKER_SOCKET/MEMENTO_WORKER_ID set;
        // never invoked by hand (and deliberately absent from the help).
        "worker" => cmd_worker(),
        "--help" | "-h" | "help" => {
            println!("{}", top_help());
            return ExitCode::SUCCESS;
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{}", top_help());
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e}");
            ExitCode::FAILURE
        }
    }
}

fn top_help() -> String {
    "memento — effortless, efficient, and reliable ML experiments\n\
     \n\
     USAGE: memento <expand|run|resume|exps|serve|daemon|submit|attach|status|report|trace|query|migrate> [options]\n\
     \n\
     Try `memento run --help` for per-command options."
        .to_string()
}

/// The CLI's experiment registry: the §3 `grid` (also the unnamed
/// fallback) plus the `echo` smoke workload. The MLP grid family needs
/// artifacts; their absence is noted unless `quiet` (listing commands and
/// spawned workers keep the console clean).
fn builtin_registry(quiet: bool) -> Registry {
    let store = shared_store().ok();
    if store.is_none() && !quiet {
        eprintln!("note: artifacts/ not found — the 'MLP' model family will fail; run `make artifacts`");
    }
    Registry::builtin(store)
}

/// `memento exps`: one line per registered experiment — name, version
/// (its id-hash salt), description — plus the unnamed-fallback rule.
fn cmd_exps(args: &[String]) -> Result<(), String> {
    let spec = CliSpec::new("memento exps", "list the experiments this binary registers");
    let _a = unwrap_cli(spec.parse(args))?;
    let registry = builtin_registry(true);
    for (name, entry) in registry.iter() {
        println!("{name:<8} {:<6} {}", entry.version, entry.description);
    }
    if registry.has_fallback() {
        println!("(unnamed tasks fall back to 'grid' and keep pre-registry task ids)");
    }
    Ok(())
}

fn unwrap_cli<T>(r: Result<T, CliError>) -> Result<T, String> {
    r.map_err(|e| match e {
        CliError::HelpRequested(h) => h,
        other => other.to_string(),
    })
}

fn cmd_expand(args: &[String]) -> Result<(), String> {
    let spec = CliSpec::new("memento expand", "show the task expansion of a config matrix")
        .positional("config", "config matrix JSON file")
        .opt("limit", "0", "print at most N tasks without a full count (0 = all)")
        .opt(
            "sample",
            "0",
            "print N tasks drawn uniformly (reservoir) from the whole \
             expansion — an unbiased preview where --limit only shows the \
             matrix's first block (0 = off)",
        )
        .opt("seed", "0", "RNG seed for --sample (deterministic previews)")
        .opt("version", "v1", "experiment code version (unnamed-task id salt)")
        .opt_required(
            "exp",
            "annotate every task with this registered experiment (see \
             `memento exps`); a reserved `exp` row parameter still wins \
             per task. Printed ids then use the entry's version salt, \
             matching what `run --exp` executes",
        )
        .flag("ids", "also print task hashes");
    let a = unwrap_cli(spec.parse(args))?;
    let path = a.pos("config").ok_or("missing <config>")?;
    let matrix = loader::from_file(Path::new(path)).map_err(|e| e.to_string())?;
    let limit = unwrap_cli(a.get_usize("limit"))?;
    let sample = unwrap_cli(a.get_usize("sample"))?;

    let registry = builtin_registry(true);
    let run_exp = a.get("exp").map(str::to_string);
    if let Some(name) = &run_exp {
        if registry.get(name).is_none() {
            return Err(format!(
                "unknown experiment '{name}' — `memento exps` lists what this binary registers"
            ));
        }
    }
    let version = a.get("version").unwrap_or("v1").to_string();
    // Same annotation the run pipeline applies, so previewed ids match
    // executed ids exactly (named tasks salt with the entry version).
    let annotate = |t: memento::coordinator::task::TaskSpec| {
        registry.annotate_spec(t, run_exp.as_deref(), &version)
    };
    let print_task = |t: &memento::coordinator::task::TaskSpec| {
        let tag = t.exp.as_ref().map(|e| format!("{}:", e.name)).unwrap_or_default();
        if a.flag("ids") {
            println!("  [{:>4}] {}  {tag}{}", t.index, t.id(&version).short(), t.label());
        } else {
            println!("  [{:>4}] {tag}{}", t.index, t.label());
        }
    };

    if sample > 0 && limit > 0 {
        return Err(
            "--limit and --sample are mutually exclusive: --limit bounds the walk to the \
             matrix's first block, --sample walks the whole stream for an unbiased draw"
                .into(),
        );
    }

    if sample > 0 {
        // Unbiased preview: one lazy pass, O(sample) memory. Costs a full
        // stream walk (like the complete listing) but never materializes
        // the task list, and — unlike --limit — every included task is
        // equally likely to appear regardless of its position.
        let seed = unwrap_cli(a.get_u64("seed"))?;
        let mut rng = memento::util::rng::Rng::new(seed);
        let (tasks, seen) =
            expand::reservoir_sample(expand::Expansion::new(&matrix), sample, &mut rng);
        println!("raw combinations : {}", matrix.raw_count());
        println!("included tasks   : {seen}");
        println!(
            "sampled          : {} of {seen} task(s), uniform, seed {seed}",
            tasks.len()
        );
        for t in tasks {
            print_task(&annotate(t));
        }
        return Ok(());
    }

    if limit > 0 {
        // Bounded preview: never walks (let alone materializes) the full
        // product, so this works on matrices with 10¹²⁺ raw combinations.
        println!("raw combinations : {}", matrix.raw_count());
        println!("showing first    : {limit} included task(s)");
        for t in expand::Expansion::new(&matrix).take(limit) {
            print_task(&annotate(t));
        }
        return Ok(());
    }

    // Full listing, streamed — counts via a lazy pass, tasks printed as
    // the second pass yields them; the task list is never held in memory.
    let included = expand::count_included(&matrix);
    println!(
        "raw combinations : {}\nexcluded         : {}\nincluded tasks   : {}",
        matrix.raw_count(),
        matrix.raw_count() - included,
        included
    );
    for t in expand::Expansion::new(&matrix) {
        print_task(&annotate(t));
    }
    Ok(())
}

fn run_spec(name: &'static str) -> CliSpec {
    CliSpec::new(name, "run registered experiments over a config matrix (default: the §3 grid)")
        .positional("config", "config matrix JSON file")
        .opt("workers", "0", "worker threads (0 = all cores)")
        .opt("seed", "0", "base RNG seed")
        .opt("version", "v1", "experiment code version (unnamed-task cache salt)")
        .opt_required(
            "exp",
            "run every task as this registered experiment (see `memento \
             exps`); a reserved `exp` row parameter still wins per task. \
             Named tasks salt their ids with the entry's version, not \
             --version",
        )
        .opt_required("cache", "result cache directory")
        .opt_required(
            "store-dir",
            "segment-log result database shared across runs: results are \
             deduplicated against every prior run and queryable afterwards \
             with `memento query <dir>` (--cache overrides it as the cache \
             backing; checkpoints move into the store too)",
        )
        .opt_required("checkpoint", "checkpoint run directory")
        .opt_required("out", "write results JSON here")
        .opt_required("journal", "write a JSONL event journal here")
        .opt("rows", "dataset", "report pivot rows")
        .opt("cols", "model", "report pivot columns")
        .opt("metric", "accuracy", "report metric field")
        .opt(
            "isolation",
            "thread",
            "execution tier: thread | process | remote",
        )
        .opt(
            "crash-budget",
            "3",
            "worker respawns per slot (process isolation)",
        )
        .opt(
            "listen",
            "127.0.0.1:0",
            "worker-registration bind address (remote isolation); the \
             resolved endpoint is printed so `memento serve --connect` \
             invocations can be pointed at it",
        )
        .opt_required(
            "token-file",
            "file holding the shared worker auth token (remote isolation)",
        )
        .opt(
            "task-timeout",
            "0",
            "per-task wall-clock budget in seconds (process/remote \
             isolation): a stuck attempt is stopped, journaled as a \
             timeout, and requeued under the retry policy (0 = unbounded)",
        )
        .opt(
            "wire",
            "binary",
            "payload encoding on the wire and at rest: binary (compact \
             tagged codec) | json (debugging; pre-v3 peers). Reads \
             auto-detect, so either setting opens existing stores",
        )
        .opt(
            "output",
            "summary",
            "output mode: summary (table at the end) | ndjson (one JSON \
             line per task outcome, streamed live)",
        )
        .opt(
            "event-cap",
            "0",
            "bound the live event channel at N undelivered events \
             (0 = unbounded). Terminal events are never dropped; progress \
             events coalesce under pressure",
        )
        .opt_required(
            "trace-dir",
            "record per-attempt span timelines into <dir>/trace.jsonl \
             (all isolation tiers; off unless set) — analyze afterwards \
             with `memento trace summarize <dir>`",
        )
        .opt(
            "telemetry-every",
            "0",
            "emit a live metrics snapshot every N seconds (with --output \
             ndjson it is printed as a `telemetry` line; 0 = off)",
        )
        .flag("fail-fast", "abort on first failure")
        .flag("quiet", "suppress progress/notifications")
}

fn cmd_run(args: &[String], resuming: bool) -> Result<(), String> {
    let spec = run_spec(if resuming { "memento resume" } else { "memento run" });
    let a = unwrap_cli(spec.parse(args))?;
    let path = a.pos("config").ok_or("missing <config>")?;
    let matrix = loader::from_file(Path::new(path)).map_err(|e| e.to_string())?;

    let wire_arg = a.get("wire").unwrap_or("binary");
    let wire = memento::util::codec::WireFormat::parse_arg(wire_arg)
        .ok_or_else(|| format!("--wire must be 'binary' or 'json', got '{wire_arg}'"))?;
    // The full built-in registry: tasks pick `grid` (the fallback, so a
    // plain run keeps its pre-registry ids and caches), `echo`, or
    // whatever `--exp` / a row-level `exp` parameter names.
    let mut m = Memento::with_registry(builtin_registry(false))
        .seed(unwrap_cli(a.get_u64("seed"))?)
        .version(a.get("version").unwrap_or("v1"))
        .wire_format(wire)
        .fail_fast(a.flag("fail-fast"));
    if let Some(name) = a.get("exp") {
        // Validated at launch: an unknown name is a config error there.
        m = m.exp(name);
    }
    let workers = unwrap_cli(a.get_usize("workers"))?;
    if workers > 0 {
        m = m.workers(workers);
    }
    let task_timeout = unwrap_cli(a.get_f64("task-timeout"))?;
    if task_timeout > 0.0 {
        m = m.task_timeout(Duration::from_secs_f64(task_timeout));
    }
    match a.get("isolation").unwrap_or("thread") {
        "thread" => {}
        "process" => {
            let n = if workers > 0 { workers } else { memento::util::pool::num_cpus() };
            let budget = unwrap_cli(a.get_usize("crash-budget"))? as u32;
            // Workers re-execute this binary via the hidden `worker`
            // subcommand and run the same grid experiment function.
            m = m
                .isolate_processes(n, budget)
                .worker_args(vec!["worker".to_string()]);
        }
        "remote" => {
            m = setup_remote(m, &a, workers)?;
        }
        other => {
            return Err(format!(
                "--isolation must be 'thread', 'process', or 'remote', got '{other}'"
            ))
        }
    }
    if let Some(dir) = a.get("store-dir") {
        let store = memento::store::ResultStore::open(dir)
            .map_err(|e| format!("cannot open store {dir}: {e}"))?;
        for w in store.open_warnings() {
            eprintln!("store warning: {w}");
        }
        m = m.with_store(store);
    }
    if let Some(dir) = a.get("cache") {
        m = m.with_cache_dir(dir);
    }
    if let Some(path) = a.get("journal") {
        m = m.with_journal(path);
    }
    if let Some(dir) = a.get("checkpoint") {
        m = m.with_checkpoint_dir(dir);
    } else if resuming {
        return Err("resume requires --checkpoint <dir>".into());
    }
    let event_cap = unwrap_cli(a.get_usize("event-cap"))?;
    if event_cap > 0 {
        m = m.event_capacity(event_cap);
    }
    if let Some(dir) = a.get("trace-dir") {
        m = m.trace_to(dir);
    }
    let telemetry = unwrap_cli(a.get_f64("telemetry-every"))?;
    if telemetry > 0.0 {
        m = m.telemetry_every(Duration::from_secs_f64(telemetry));
    }
    let ndjson = match a.get("output").unwrap_or("summary") {
        "summary" => false,
        "ndjson" => true,
        other => {
            return Err(format!(
                "--output must be 'summary' or 'ndjson', got '{other}'"
            ))
        }
    };
    if !a.flag("quiet") && !ndjson {
        m = m
            .with_notifier(Box::new(ConsoleNotificationProvider))
            .progress_every(Duration::from_secs(2));
    }

    let metrics = m.metrics();
    let started = std::time::Instant::now();
    let results = if ndjson {
        // Streaming mode: launch returns immediately; each task outcome is
        // printed as one JSON line the moment it completes (restored tasks
        // included), plus worker-crash and final run_complete lines.
        // stdout stays machine-parseable; bookkeeping goes to stderr.
        let run = if resuming { m.launch_resume(&matrix) } else { m.launch(&matrix) }
            .map_err(|e| e.to_string())?;
        for event in run.events() {
            match &event {
                RunEvent::TaskFinished(_)
                | RunEvent::WorkerCrashed { .. }
                | RunEvent::Telemetry(_)
                | RunEvent::RunComplete(_) => println!("{}", event.to_json()),
                _ => {}
            }
        }
        run.collect().map_err(|e| e.to_string())?
    } else {
        if resuming { m.resume(&matrix) } else { m.run(&matrix) }.map_err(|e| e.to_string())?
    };
    let wall = started.elapsed().as_secs_f64();

    if !ndjson {
        println!("\n{}", results.summary());
        print!("{}", metrics.render(wall));
        for o in results.failures() {
            if let Some(f) = &o.failure {
                println!("FAILED: {}", f.summary());
            }
        }

        let pivot = results.pivot(
            a.get("rows").unwrap_or("dataset"),
            a.get("cols").unwrap_or("model"),
            a.get("metric").unwrap_or("accuracy"),
        );
        println!("\n{}", pivot.render());
    }

    if let Some(out) = a.get("out") {
        memento::util::fs::atomic_write(Path::new(out), results.to_json().pretty().as_bytes())
            .map_err(|e| e.to_string())?;
        if ndjson {
            eprintln!("results written to {out}");
        } else {
            println!("results written to {out}");
        }
    }
    Ok(())
}

/// Reads the shared worker auth token from a file (trimmed; must be
/// non-empty). Distributing the secret via a file keeps it out of argv
/// and the process table.
fn read_token_file(path: &str) -> Result<String, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read token file {path}: {e}"))?;
    let token = text.trim().to_string();
    if token.is_empty() {
        return Err(format!("token file {path} is empty"));
    }
    Ok(token)
}

/// `--isolation remote`: bind the worker-registration pool here in the
/// CLI (not inside the run) so the resolved endpoint can be printed
/// before dispatch — operators paste it into their `memento serve
/// --connect` invocations.
#[cfg(unix)]
fn setup_remote(
    m: Memento,
    a: &memento::util::cli::CliArgs,
    workers: usize,
) -> Result<Memento, String> {
    use memento::ipc::pool::{PoolOptions, WorkerPool};
    use memento::ipc::transport::Transport;

    let token_path = a
        .get("token-file")
        .ok_or("--isolation remote requires --token-file (the shared worker auth token)")?;
    let token = read_token_file(token_path)?;
    let bind = a.get("listen").unwrap_or("127.0.0.1:0").to_string();
    let pool = WorkerPool::listen(
        &Transport::Tcp { bind },
        PoolOptions { token: Some(token), ..PoolOptions::default() },
    )
    .map_err(|e| e.to_string())?;
    eprintln!(
        "memento: listening for workers on {} — start them with `memento serve --connect {} --token-file {token_path}`",
        pool.endpoint(),
        pool.endpoint().to_string().trim_start_matches("tcp://"),
    );
    let n = if workers > 0 { workers } else { memento::util::pool::num_cpus() };
    Ok(m
        .with_worker_pool(pool)
        // The bind address in the backend is unused once a pool is
        // installed; the pool above owns the listener.
        .remote_workers("", n))
}

#[cfg(not(unix))]
fn setup_remote(
    _m: Memento,
    _a: &memento::util::cli::CliArgs,
    _workers: usize,
) -> Result<Memento, String> {
    Err("remote isolation requires a unix platform".into())
}

/// Parsed `memento serve` arguments — shared by the unix dispatch path
/// and the non-unix stub so the flag surface (and `--help` text) can
/// never drift between platforms. Only the dispatch itself is cfg-gated.
#[cfg_attr(not(unix), allow(dead_code))]
struct ServeConfig {
    addr: String,
    token: String,
    worker_id: u64,
    runs: usize,
    tasks_per_conn: usize,
    give_up: f64,
    wire: memento::util::codec::WireFormat,
    /// `--exps a,b`: serve a subset of the binary's registered
    /// experiments (None = all of them).
    exps: Option<Vec<String>>,
}

fn parse_serve_args(args: &[String]) -> Result<ServeConfig, String> {
    let spec = CliSpec::new(
        "memento serve",
        "standing worker: register with a remote supervisor and execute registered experiments",
    )
    .opt_required("connect", "supervisor address (host:port)")
    .opt_required("token-file", "file holding the shared auth token")
    .opt("worker-id", "0", "self-reported worker id (diagnostics)")
    .opt("runs", "0", "stop after serving N runs (0 = serve forever)")
    .opt(
        "tasks-per-conn",
        "0",
        "voluntarily re-register after N task attempts per connection \
         (0 = never); useful for rolling restarts",
    )
    .opt(
        "give-up-after",
        "0",
        "exit once the supervisor has been unreachable for N seconds \
         (0 = keep retrying forever)",
    )
    .opt(
        "wire",
        "binary",
        "highest payload encoding this worker will speak: binary | json \
         (the supervisor's Hello picks the session format; json forces \
         plain-JSON frames for debugging)",
    )
    .opt_required(
        "exps",
        "comma-separated subset of registered experiments to advertise \
         and serve (default: all — see `memento exps`); the supervisor \
         only dispatches named tasks this worker advertised",
    );
    let a = unwrap_cli(spec.parse(args))?;
    let addr = a.get("connect").ok_or("missing --connect")?.to_string();
    let token = read_token_file(a.get("token-file").ok_or("missing --token-file")?)?;
    let wire_arg = a.get("wire").unwrap_or("binary");
    let wire = memento::util::codec::WireFormat::parse_arg(wire_arg)
        .ok_or_else(|| format!("--wire must be 'binary' or 'json', got '{wire_arg}'"))?;
    let exps = a.get("exps").map(|s| {
        s.split(',')
            .map(|p| p.trim().to_string())
            .filter(|p| !p.is_empty())
            .collect::<Vec<String>>()
    });
    Ok(ServeConfig {
        addr,
        token,
        worker_id: unwrap_cli(a.get_u64("worker-id"))?,
        runs: unwrap_cli(a.get_usize("runs"))?,
        tasks_per_conn: unwrap_cli(a.get_usize("tasks-per-conn"))?,
        give_up: unwrap_cli(a.get_f64("give-up-after"))?,
        wire,
        exps,
    })
}

/// `memento serve`: a standing worker process. Connects out to a
/// supervisor started with `--isolation remote`, authenticates with the
/// shared token, advertises its registered experiment names, serves task
/// attempts, and re-registers after every run (reconnecting with backoff
/// if the supervisor is unreachable) until stopped — or until the
/// optional bounds in [`parse_serve_args`].
#[cfg(unix)]
fn cmd_serve(args: &[String]) -> Result<(), String> {
    use memento::ipc::transport::Endpoint;
    use memento::ipc::worker::{serve_remote, RemoteWorkerOptions};

    let cfg = parse_serve_args(args)?;
    let mut registry = builtin_registry(false);
    if let Some(names) = &cfg.exps {
        registry = registry.subset(names).map_err(|e| e.to_string())?;
    }
    let endpoint = Endpoint::Tcp(cfg.addr.clone());
    eprintln!(
        "memento serve: registering with {endpoint} (exps: {})",
        registry.names().join(", ")
    );
    let report = serve_remote(
        std::sync::Arc::new(registry),
        &endpoint,
        RemoteWorkerOptions {
            token: Some(cfg.token),
            worker_id: cfg.worker_id,
            max_connections: (cfg.runs > 0).then_some(cfg.runs),
            tasks_per_connection: (cfg.tasks_per_conn > 0).then_some(cfg.tasks_per_conn),
            give_up_after: (cfg.give_up > 0.0).then(|| Duration::from_secs_f64(cfg.give_up)),
            wire: cfg.wire,
            ..RemoteWorkerOptions::default()
        },
    )
    .map_err(|e| e.to_string())?;
    eprintln!(
        "memento serve: done — {} connection(s), {} task attempt(s)",
        report.connections, report.tasks
    );
    Ok(())
}

#[cfg(not(unix))]
fn cmd_serve(args: &[String]) -> Result<(), String> {
    // Parse first so `--help` and flag errors behave identically to unix.
    let _ = parse_serve_args(args)?;
    Err("memento serve requires a unix platform".into())
}

/// Parsed `memento daemon` arguments — like [`ServeConfig`], parsing is
/// platform-neutral so `--help` and flag errors match on every OS.
#[cfg_attr(not(unix), allow(dead_code))]
struct DaemonCliConfig {
    root: Option<String>,
    listen: String,
    worker_listen: String,
    token: Option<String>,
    max_queue: usize,
    max_in_flight: usize,
    workers: usize,
    wire: memento::util::codec::WireFormat,
    version: String,
    task_timeout: f64,
    stop: bool,
    connect: Option<String>,
}

fn parse_daemon_args(args: &[String]) -> Result<DaemonCliConfig, String> {
    let spec = CliSpec::new(
        "memento daemon",
        "multi-tenant run-submission service: one shared worker pool and result \
         store, many concurrent `memento submit` clients",
    )
    .opt_required("root", "daemon state root (holds store/, runs/, pending/)")
    .opt(
        "listen",
        "127.0.0.1:7461",
        "client (submit/attach/status) bind address — host:port, or 'unix' \
         for a private same-host socket",
    )
    .opt(
        "worker-listen",
        "127.0.0.1:7462",
        "worker-registration bind address — host:port, or 'unix'; point \
         `memento serve --connect` here",
    )
    .opt_required(
        "token-file",
        "file holding the shared auth token clients AND workers present \
         (required when either listener is TCP)",
    )
    .opt("max-queue", "64", "queued submissions before Submit is rejected")
    .opt("max-in-flight", "2", "concurrently running runs per tenant")
    .opt("workers", "2", "remote worker slots each run schedules onto")
    .opt("version", "v1", "default experiment version for submissions that don't pin one")
    .opt(
        "task-timeout",
        "0",
        "per-task wall-clock budget in seconds applied to every run (0 = unbounded)",
    )
    .opt("wire", "binary", "store/journal payload encoding: binary | json")
    .flag("stop", "instead of serving: ask the daemon at --connect to drain and exit")
    .opt_required("connect", "with --stop: the daemon's client address");
    let a = unwrap_cli(spec.parse(args))?;
    let token = match a.get("token-file") {
        Some(path) => Some(read_token_file(path)?),
        None => None,
    };
    let wire_arg = a.get("wire").unwrap_or("binary");
    let wire = memento::util::codec::WireFormat::parse_arg(wire_arg)
        .ok_or_else(|| format!("--wire must be 'binary' or 'json', got '{wire_arg}'"))?;
    Ok(DaemonCliConfig {
        root: a.get("root").map(str::to_string),
        listen: a.get("listen").unwrap_or("127.0.0.1:7461").to_string(),
        worker_listen: a.get("worker-listen").unwrap_or("127.0.0.1:7462").to_string(),
        token,
        max_queue: unwrap_cli(a.get_usize("max-queue"))?,
        max_in_flight: unwrap_cli(a.get_usize("max-in-flight"))?,
        workers: unwrap_cli(a.get_usize("workers"))?,
        wire,
        version: a.get("version").unwrap_or("v1").to_string(),
        task_timeout: unwrap_cli(a.get_f64("task-timeout"))?,
        stop: a.flag("stop"),
        connect: a.get("connect").map(str::to_string),
    })
}

/// Client-address parsing for the daemon verbs: an absolute or relative
/// path is a Unix socket, everything else is `host:port` (an explicit
/// `tcp://` prefix also works).
#[cfg(unix)]
fn parse_daemon_endpoint(addr: &str) -> memento::ipc::transport::Endpoint {
    use memento::ipc::transport::Endpoint;
    if let Some(rest) = addr.strip_prefix("tcp://") {
        return Endpoint::Tcp(rest.to_string());
    }
    if addr.starts_with('/') || addr.starts_with("./") {
        return Endpoint::Unix(addr.into());
    }
    Endpoint::Tcp(addr.to_string())
}

/// Bind-address parsing for the daemon listeners: `unix` = a private
/// socket in a fresh temp dir, anything else = a TCP `host:port`.
#[cfg(unix)]
fn parse_daemon_bind(addr: &str) -> memento::ipc::transport::Transport {
    use memento::ipc::transport::Transport;
    if addr == "unix" {
        Transport::Unix
    } else {
        Transport::Tcp { bind: addr.to_string() }
    }
}

/// `memento daemon`: start (or, with `--stop`, drain) the multi-tenant
/// submission service. Serves until a drain is requested over the wire,
/// then exits once in-flight runs have drained — queued submissions stay
/// pending on disk and resume on the next start.
#[cfg(unix)]
fn cmd_daemon(args: &[String]) -> Result<(), String> {
    use memento::daemon::{Daemon, DaemonClient, DaemonOptions};

    let cfg = parse_daemon_args(args)?;
    if cfg.stop {
        let addr = cfg.connect.as_deref().ok_or("--stop requires --connect <addr>")?;
        let client = DaemonClient::new(parse_daemon_endpoint(addr), cfg.token);
        client.request_shutdown().map_err(|e| e.to_string())?;
        eprintln!("memento daemon: drain requested at {addr}");
        return Ok(());
    }
    let root = cfg.root.as_deref().ok_or("missing --root <dir>")?;
    let mut options = DaemonOptions::new(root);
    options.token = cfg.token;
    options.max_queue = cfg.max_queue;
    options.max_in_flight = cfg.max_in_flight;
    options.workers_per_run = cfg.workers;
    options.wire = cfg.wire;
    options.version = cfg.version.clone();
    if cfg.task_timeout > 0.0 {
        options.task_timeout = Some(Duration::from_secs_f64(cfg.task_timeout));
    }
    let daemon = Daemon::start(
        builtin_registry(false),
        options,
        &parse_daemon_bind(&cfg.listen),
        &parse_daemon_bind(&cfg.worker_listen),
    )
    .map_err(|e| e.to_string())?;
    let workers = daemon.worker_endpoint();
    eprintln!("memento daemon: clients on {}", daemon.endpoint());
    eprintln!(
        "memento daemon: workers on {workers} — start them with `memento serve --connect {} --token-file <file>`",
        workers.to_string().trim_start_matches("tcp://"),
    );
    daemon.wait();
    eprintln!("memento daemon: drained, exiting");
    Ok(())
}

#[cfg(not(unix))]
fn cmd_daemon(args: &[String]) -> Result<(), String> {
    let _ = parse_daemon_args(args)?;
    Err("memento daemon requires a unix platform".into())
}

/// Flags shared by `memento submit` and `memento attach`.
#[cfg_attr(not(unix), allow(dead_code))]
fn daemon_client_spec(spec: CliSpec) -> CliSpec {
    spec.opt_required("connect", "daemon client address (host:port, or a unix socket path)")
        .opt_required("token-file", "file holding the shared auth token")
        .opt(
            "output",
            "summary",
            "output mode: summary (progress lines + totals) | ndjson (one \
             JSON event document per line, machine-parseable)",
        )
}

/// Connection half shared by `submit`/`attach`/`status --daemon`.
#[cfg_attr(not(unix), allow(dead_code))]
struct DaemonConn {
    addr: String,
    token: Option<String>,
    ndjson: bool,
}

#[cfg_attr(not(unix), allow(dead_code))]
fn parse_daemon_conn(a: &memento::util::cli::CliArgs) -> Result<DaemonConn, String> {
    let addr = a.get("connect").ok_or("missing --connect <addr>")?.to_string();
    let token = match a.get("token-file") {
        Some(path) => Some(read_token_file(path)?),
        None => None,
    };
    let ndjson = match a.get("output").unwrap_or("summary") {
        "summary" => false,
        "ndjson" => true,
        other => return Err(format!("--output must be 'summary' or 'ndjson', got '{other}'")),
    };
    Ok(DaemonConn { addr, token, ndjson })
}

/// Follows a daemon run's event stream to completion. Summary mode
/// prints one line per finished task plus the totals; ndjson prints the
/// raw event documents. The exit code reflects the run: failures, an
/// abort, a drain-cancellation, or a launch error all return `Err`.
#[cfg(unix)]
fn stream_daemon_run(mut handle: memento::daemon::RunHandle, ndjson: bool) -> Result<(), String> {
    let mut outcome: Option<String> = None;
    while let Some(ev) = handle.next_event().map_err(|e| e.to_string())? {
        let kind = ev.get("event").and_then(|j| j.as_str()).unwrap_or("").to_string();
        if ndjson {
            println!("{ev}");
        } else {
            match kind.as_str() {
                "task_finished" => {
                    let id = ev.get("id").and_then(|j| j.as_str()).unwrap_or("?");
                    let status = ev.get("status").and_then(|j| j.as_str()).unwrap_or("?");
                    let cached =
                        ev.get("from_cache").and_then(|j| j.as_bool()).unwrap_or(false);
                    println!(
                        "task {:<12} {status}{}",
                        &id[..12.min(id.len())],
                        if cached { " (cached)" } else { "" }
                    );
                }
                "worker_crashed" => {
                    let msg = ev.get("message").and_then(|j| j.as_str()).unwrap_or("?");
                    eprintln!("worker crashed: {msg}");
                }
                "run_complete" => {
                    println!(
                        "run complete: {} task(s), {} succeeded, {} failed, {} from cache, {} skipped",
                        ev.get("total").and_then(|j| j.as_i64()).unwrap_or(0),
                        ev.get("succeeded").and_then(|j| j.as_i64()).unwrap_or(0),
                        ev.get("failed").and_then(|j| j.as_i64()).unwrap_or(0),
                        ev.get("from_cache").and_then(|j| j.as_i64()).unwrap_or(0),
                        ev.get("skipped").and_then(|j| j.as_i64()).unwrap_or(0),
                    );
                }
                "run_error" => {
                    let msg = ev.get("message").and_then(|j| j.as_str()).unwrap_or("?");
                    eprintln!("run error: {msg}");
                }
                _ => {}
            }
        }
        match kind.as_str() {
            "run_complete" => {
                let failed = ev.get("failed").and_then(|j| j.as_i64()).unwrap_or(0);
                let aborted = ev.get("aborted").and_then(|j| j.as_bool()).unwrap_or(false);
                let cancelled = ev.get("cancelled").and_then(|j| j.as_bool()).unwrap_or(false);
                outcome = if aborted {
                    Some("run aborted".to_string())
                } else if cancelled {
                    Some("run cancelled (daemon drain)".to_string())
                } else if failed > 0 {
                    Some(format!("run completed with {failed} failure(s)"))
                } else {
                    None
                };
            }
            "run_error" => {
                let msg = ev.get("message").and_then(|j| j.as_str()).unwrap_or("?");
                outcome = Some(format!("run failed to launch: {msg}"));
            }
            _ => {}
        }
    }
    match outcome {
        Some(err) => Err(err),
        None => Ok(()),
    }
}

/// `memento submit`: send a config matrix to a running daemon and (unless
/// `--detach`) follow its event stream. The printed run id re-attaches
/// later with `memento attach`.
#[cfg(unix)]
fn cmd_submit(args: &[String]) -> Result<(), String> {
    use memento::daemon::{DaemonClient, SubmitOptions};

    let spec = daemon_client_spec(
        CliSpec::new("memento submit", "submit a config matrix to a running daemon")
            .positional("config", "config matrix JSON file"),
    )
    .opt("tenant", "default", "tenant to account the run under (quota + store label)")
    .opt_required("exp", "run every task as this registered experiment (daemon-side registry)")
    .opt_required("version", "experiment version override (daemon default if absent)")
    .opt("seed", "0", "base RNG seed")
    .opt_required("label", "human-chosen run label (duplicate labels are rejected)")
    .flag("detach", "print the accepted run id and exit without following events");
    let a = unwrap_cli(spec.parse(args))?;
    let conn = parse_daemon_conn(&a)?;
    let path = a.pos("config").ok_or("missing <config>")?;
    let matrix = loader::from_file(Path::new(path)).map_err(|e| e.to_string())?;
    let client = DaemonClient::new(parse_daemon_endpoint(&conn.addr), conn.token);
    let handle = client
        .submit(
            &matrix,
            &SubmitOptions {
                tenant: a.get("tenant").unwrap_or("default").to_string(),
                exp: a.get("exp").map(str::to_string),
                version: a.get("version").map(str::to_string),
                seed: unwrap_cli(a.get_u64("seed"))?,
                label: a.get("label").map(str::to_string),
            },
        )
        .map_err(|e| e.to_string())?;
    eprintln!("memento submit: accepted as run {}", handle.run_id());
    if a.flag("detach") {
        println!("{}", handle.run_id());
        handle.detach();
        return Ok(());
    }
    stream_daemon_run(handle, conn.ndjson)
}

#[cfg(not(unix))]
fn cmd_submit(_args: &[String]) -> Result<(), String> {
    Err("memento submit requires a unix platform".into())
}

/// `memento attach`: resume a daemon run's event stream. Terminal events
/// the client missed (including whole runs finished in an earlier daemon
/// life) are replayed first.
#[cfg(unix)]
fn cmd_attach(args: &[String]) -> Result<(), String> {
    use memento::daemon::DaemonClient;

    let spec = daemon_client_spec(
        CliSpec::new("memento attach", "re-attach to a daemon run's event stream")
            .positional("run-id", "run id printed by `memento submit`"),
    );
    let a = unwrap_cli(spec.parse(args))?;
    let conn = parse_daemon_conn(&a)?;
    let run_id = a.pos("run-id").ok_or("missing <run-id>")?;
    let client = DaemonClient::new(parse_daemon_endpoint(&conn.addr), conn.token);
    let handle = client.attach(run_id).map_err(|e| e.to_string())?;
    stream_daemon_run(handle, conn.ndjson)
}

#[cfg(not(unix))]
fn cmd_attach(_args: &[String]) -> Result<(), String> {
    Err("memento attach requires a unix platform".into())
}

/// The `status --daemon` section: fetch and render the daemon's live
/// status document.
#[cfg(unix)]
fn print_daemon_status(addr: &str, token: Option<String>) -> Result<(), String> {
    use memento::daemon::DaemonClient;

    let client = DaemonClient::new(parse_daemon_endpoint(addr), token);
    let doc = client.status().map_err(|e| e.to_string())?;
    let daemon = doc.get("daemon");
    println!(
        "daemon    : {addr} — up {:.1}s{}",
        daemon.and_then(|d| d.get("uptime_secs")).and_then(|j| j.as_f64()).unwrap_or(0.0),
        if daemon.and_then(|d| d.get("draining")).and_then(|j| j.as_bool()).unwrap_or(false) {
            " (draining)"
        } else {
            ""
        },
    );
    if let Some(q) = doc.get("queue") {
        println!(
            "queue     : {} waiting of {} (quota {}/tenant)",
            q.get("depth").and_then(|j| j.as_i64()).unwrap_or(0),
            q.get("max").and_then(|j| j.as_i64()).unwrap_or(0),
            q.get("max_in_flight").and_then(|j| j.as_i64()).unwrap_or(0),
        );
    }
    if let Some(p) = doc.get("pool") {
        println!(
            "pool      : {} worker(s) registered, {} available, {} leased, {} run(s) waiting",
            p.get("registered").and_then(|j| j.as_i64()).unwrap_or(0),
            p.get("available").and_then(|j| j.as_i64()).unwrap_or(0),
            p.get("leased").and_then(|j| j.as_i64()).unwrap_or(0),
            p.get("waiting").and_then(|j| j.as_i64()).unwrap_or(0),
        );
    }
    if let Some(s) = doc.get("store") {
        println!(
            "store     : {} segment(s), {} live record(s), {} dedup hit(s), {} run(s)",
            s.get("segments").and_then(|j| j.as_i64()).unwrap_or(0),
            s.get("live_records").and_then(|j| j.as_i64()).unwrap_or(0),
            s.get("dedup_hits").and_then(|j| j.as_i64()).unwrap_or(0),
            s.get("runs").and_then(|j| j.as_i64()).unwrap_or(0),
        );
    }
    if let Some(runs) = doc.get("runs").and_then(|j| j.as_arr()) {
        if !runs.is_empty() {
            println!("runs      :");
            for r in runs {
                println!(
                    "  {:<40} {:<12} {}",
                    r.get("run_id").and_then(|j| j.as_str()).unwrap_or("?"),
                    r.get("tenant").and_then(|j| j.as_str()).unwrap_or("?"),
                    r.get("phase").and_then(|j| j.as_str()).unwrap_or("?"),
                );
            }
        }
    }
    Ok(())
}

#[cfg(not(unix))]
fn print_daemon_status(_addr: &str, _token: Option<String>) -> Result<(), String> {
    Err("status --daemon requires a unix platform".into())
}

/// The hidden worker mode behind `--isolation process`: connect to the
/// supervisor socket named by the environment, execute tasks against the
/// full built-in registry, exit.
#[cfg(unix)]
fn cmd_worker() -> Result<(), String> {
    if !memento::ipc::worker::active() {
        return Err(
            "`memento worker` is internal: it is spawned by `memento run --isolation \
             process` with the worker environment set"
                .into(),
        );
    }
    // Quiet: the supervisor owns the console; missing-artifact failures
    // surface per task instead.
    memento::ipc::worker::serve(std::sync::Arc::new(builtin_registry(true)))
        .map_err(|e| e.to_string())
}

#[cfg(not(unix))]
fn cmd_worker() -> Result<(), String> {
    Err("process isolation requires a unix platform".into())
}

fn cmd_status(args: &[String]) -> Result<(), String> {
    let spec = CliSpec::new(
        "memento status",
        "inspect a run: checkpoint manifest, latest telemetry snapshot, trace summary",
    )
    .opt_required("checkpoint", "checkpoint run directory")
    .opt_required(
        "trace",
        "trace directory written by `run --trace-dir` — prints the \
         persisted metrics snapshot and a span-timeline summary",
    )
    .opt_required(
        "store",
        "segment-log store directory written by `run --store-dir` — \
         prints segment counts, live/dead record ratio, index shard \
         occupancy, and cross-run dedup hits",
    )
    .opt_required(
        "daemon",
        "daemon client address (host:port or unix socket path) — prints \
         the live status document: queue depth, per-tenant in-flight \
         runs, pool and store health",
    )
    .opt_required("token-file", "file holding the daemon auth token (with --daemon)");
    let a = unwrap_cli(spec.parse(args))?;
    let (ck_dir, trace_dir, store_dir) = (a.get("checkpoint"), a.get("trace"), a.get("store"));
    let daemon_addr = a.get("daemon");
    if ck_dir.is_none() && trace_dir.is_none() && store_dir.is_none() && daemon_addr.is_none() {
        return Err(
            "status needs --checkpoint <dir>, --trace <dir>, --store <dir>, and/or --daemon <addr>"
                .into(),
        );
    }
    if let Some(addr) = daemon_addr {
        let token = match a.get("token-file") {
            Some(path) => Some(read_token_file(path)?),
            None => None,
        };
        print_daemon_status(addr, token)?;
    }
    if let Some(dir) = store_dir {
        print_store_status(dir)?;
    }
    if let Some(dir) = ck_dir {
        let manifest = Path::new(dir).join("manifest.json");
        // read_document auto-detects tagged-binary vs JSON content, so
        // status inspects manifests written under either --wire setting.
        let bytes = std::fs::read(&manifest)
            .map_err(|e| format!("cannot read {}: {e}", manifest.display()))?;
        let doc = memento::util::codec::read_document(&bytes).map_err(|e| e.to_string())?;
        let total = doc.get("total_tasks").and_then(|j| j.as_i64()).unwrap_or(0);
        let completed = doc
            .get("completed")
            .and_then(|j| j.as_obj())
            .map(|o| o.len())
            .unwrap_or(0);
        let failed = doc
            .get("completed")
            .and_then(|j| j.as_obj())
            .map(|o| o.values().filter(|e| e.get("failed").is_some()).count())
            .unwrap_or(0);
        println!(
            "run dir   : {dir}\nmatrix    : {}\nversion   : {}\nprogress  : {completed}/{total} completed ({failed} failed)",
            doc.get("matrix_fingerprint")
                .and_then(|j| j.as_str())
                .map(|s| &s[..12.min(s.len())])
                .unwrap_or("?"),
            doc.get("version").and_then(|j| j.as_str()).unwrap_or("?"),
        );
    }
    if let Some(dir) = trace_dir {
        let dir = Path::new(dir);
        match memento::obs::snapshot::read_snapshot(dir) {
            Some(snap) => print!("{}", snap.render()),
            None => println!("no metrics snapshot in {}", dir.display()),
        }
        let trace_path = dir.join(memento::obs::trace::TRACE_FILE);
        if trace_path.exists() {
            let parsed =
                memento::obs::trace::read_trace(&trace_path).map_err(|e| e.to_string())?;
            print!("{}", memento::obs::trace::summarize(&parsed.spans, 3).render());
        } else {
            println!("no trace file in {}", dir.display());
        }
    }
    Ok(())
}

/// The `status --store` section: segment-log health at a glance.
fn print_store_status(dir: &str) -> Result<(), String> {
    let store = memento::store::ResultStore::open(dir)
        .map_err(|e| format!("cannot open store {dir}: {e}"))?;
    let stats = store.stats();
    let dead_pct = if stats.total_records > 0 {
        100.0 * stats.dead_records as f64 / stats.total_records as f64
    } else {
        0.0
    };
    println!(
        "store     : {dir}\n\
         segments  : {} ({} sealed)\n\
         records   : {} live / {} dead of {} ({dead_pct:.1}% reclaimable)\n\
         dedup     : {} cross-run hit(s)\n\
         runs      : {}\n\
         compacted : {} pass(es) since open",
        stats.segments,
        stats.sealed_segments,
        stats.live_records,
        stats.dead_records,
        stats.total_records,
        stats.dedup_hits,
        stats.runs,
        stats.compactions,
    );
    let occ = stats.shard_occupancy;
    let max = occ.iter().copied().max().unwrap_or(0).max(1);
    let bars: Vec<String> = occ
        .iter()
        .map(|&n| {
            // 0–8 eighth-block glyphs per shard: a tiny occupancy sparkline.
            const BLOCKS: [&str; 9] = [" ", "▁", "▂", "▃", "▄", "▅", "▆", "▇", "█"];
            BLOCKS[(n * 8).div_ceil(max).min(8)].to_string()
        })
        .collect();
    println!("shards    : [{}] max {max} key(s)/shard", bars.join(""));
    for w in store.open_warnings() {
        println!("warning   : {w}");
    }
    Ok(())
}

/// `memento query`: predicate search over every result the store has
/// recorded, across all runs. Non-matching records are never decoded
/// past their scalar fields (see `store::query`).
fn cmd_query(args: &[String]) -> Result<(), String> {
    use memento::store::query::{parse_predicates, QueryOptions};

    let spec = CliSpec::new(
        "memento query",
        "search results across runs in a segment-log store",
    )
    .positional("store", "store directory written by `run --store-dir`")
    .opt_required(
        "where",
        "comma-separated predicates over parameter fields, e.g. \
         \"model=svc, lr<=0.1, folds!=2\" (ops: = != < <= > >=; values: \
         numbers, true/false, strings — quote to force a string match)",
    )
    .opt("last-runs", "0", "restrict to the N most recent runs (0 = all)")
    .opt("limit", "0", "stop after N matching rows (0 = unbounded)")
    .opt(
        "output",
        "table",
        "output mode: table (aligned summary columns) | ndjson (one full \
         record document per line, machine-parseable)",
    );
    let a = unwrap_cli(spec.parse(args))?;
    let dir = a.pos("store").ok_or("missing <store>")?;
    let store = memento::store::ResultStore::open(dir)
        .map_err(|e| format!("cannot open store {dir}: {e}"))?;
    for w in store.open_warnings() {
        eprintln!("store warning: {w}");
    }
    let preds = match a.get("where") {
        Some(expr) => parse_predicates(expr)?,
        None => Vec::new(),
    };
    let last_runs = unwrap_cli(a.get_usize("last-runs"))?;
    let limit = unwrap_cli(a.get_usize("limit"))?;
    let opts = QueryOptions {
        last_runs: (last_runs > 0).then_some(last_runs),
        limit: (limit > 0).then_some(limit),
    };
    let rows = store.query(&preds, &opts).map_err(|e| e.to_string())?;

    match a.get("output").unwrap_or("table") {
        "ndjson" => {
            for row in &rows {
                println!("{}", row.doc);
            }
        }
        "table" => {
            // Columns: short id, run, each queried field, then the value.
            let fields: Vec<&str> = preds.iter().map(|p| p.field.as_str()).collect();
            let mut header: Vec<String> = vec!["id".into(), "run".into()];
            header.extend(fields.iter().map(|f| f.to_string()));
            header.push("value".into());
            let mut table: Vec<Vec<String>> = vec![header];
            for row in &rows {
                let params = row.doc.get("params");
                let mut cells = vec![row.id[..12.min(row.id.len())].to_string(), row.run.clone()];
                for f in &fields {
                    let cell = params
                        .and_then(|p| p.get(f))
                        .map(|v| v.to_string())
                        .unwrap_or_else(|| "-".into());
                    cells.push(cell);
                }
                let value = row.doc.get("value").map(|v| v.to_string()).unwrap_or_default();
                cells.push(if value.chars().count() > 48 {
                    let cut: String = value.chars().take(47).collect();
                    format!("{cut}…")
                } else {
                    value
                });
                table.push(cells);
            }
            let ncols = table[0].len();
            let widths: Vec<usize> = (0..ncols)
                .map(|c| table.iter().map(|r| r[c].chars().count()).max().unwrap_or(0))
                .collect();
            for row in &table {
                let line: Vec<String> = row
                    .iter()
                    .zip(&widths)
                    .map(|(cell, &w)| format!("{cell:<w$}"))
                    .collect();
                println!("{}", line.join("  ").trim_end());
            }
            println!("{} row(s)", rows.len());
        }
        other => return Err(format!("--output must be 'table' or 'ndjson', got '{other}'")),
    }
    Ok(())
}

/// `memento migrate`: fold a legacy per-run directory layout (one JSON
/// file per cache entry / checkpoint manifest) into a segment-log store.
/// The legacy directory is left untouched; re-running is idempotent
/// because identical puts dedup against the store's content hashes.
fn cmd_migrate(args: &[String]) -> Result<(), String> {
    let spec = CliSpec::new(
        "memento migrate",
        "fold legacy per-run JSON directories into a segment-log store",
    )
    .positional("legacy", "legacy cache or checkpoint-run directory")
    .positional("store", "target store directory (created if absent)")
    .flag("keep-open", "skip sealing the active segment after migrating");
    let a = unwrap_cli(spec.parse(args))?;
    let legacy = a.pos("legacy").ok_or("missing <legacy>")?;
    let dir = a.pos("store").ok_or("missing <store>")?;
    let store = memento::store::ResultStore::open(dir)
        .map_err(|e| format!("cannot open store {dir}: {e}"))?;
    let report = store
        .migrate_dir(Path::new(legacy))
        .map_err(|e| format!("migrate {legacy}: {e}"))?;
    if !a.flag("keep-open") {
        store.seal_active().map_err(|e| e.to_string())?;
    }
    println!(
        "migrated {legacy} -> {dir}: {} result(s), {} checkpoint entr(ies), \
         {} manifest(s), {} file(s) skipped",
        report.results, report.ck_entries, report.manifests, report.skipped
    );
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<(), String> {
    let usage = "USAGE: memento trace <summarize|export> <dir> [options]\n\
                 \n\
                 summarize  worker utilization, per-phase p50/p95, critical path, stragglers\n\
                 export     convert the trace for external viewers (--format chrome)";
    let Some((sub, rest)) = args.split_first() else {
        return Err(usage.to_string());
    };
    match sub.as_str() {
        "summarize" => cmd_trace_summarize(rest),
        "export" => cmd_trace_export(rest),
        "--help" | "-h" | "help" => {
            println!("{usage}");
            Ok(())
        }
        other => Err(format!("unknown trace subcommand '{other}'\n\n{usage}")),
    }
}

/// Reads `<dir>/trace.jsonl` (either record encoding; see
/// `memento::obs::trace`).
fn read_trace_dir(dir: &str) -> Result<memento::obs::trace::TraceFile, String> {
    let path = Path::new(dir).join(memento::obs::trace::TRACE_FILE);
    memento::obs::trace::read_trace(&path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))
}

fn cmd_trace_summarize(args: &[String]) -> Result<(), String> {
    let spec = CliSpec::new("memento trace summarize", "aggregate a recorded span trace")
        .positional("dir", "trace directory (holds trace.jsonl)")
        .opt("top", "5", "number of straggler attempts to list");
    let a = unwrap_cli(spec.parse(args))?;
    let dir = a.pos("dir").ok_or("missing <dir>")?;
    let trace = read_trace_dir(dir)?;
    match (trace.footer_spans, trace.dropped) {
        (Some(spans), Some(dropped)) => {
            println!("sealed trace: footer says {spans} span(s), {dropped} dropped");
            if trace.spans.len() as u64 != spans {
                eprintln!(
                    "warning: file holds {} span(s) but the footer says {spans}",
                    trace.spans.len()
                );
            }
        }
        _ => println!(
            "live/unsealed trace: {} span(s), no footer yet",
            trace.spans.len()
        ),
    }
    let top = unwrap_cli(a.get_usize("top"))?;
    print!("{}", memento::obs::trace::summarize(&trace.spans, top).render());
    Ok(())
}

fn cmd_trace_export(args: &[String]) -> Result<(), String> {
    let spec = CliSpec::new("memento trace export", "convert a span trace for external viewers")
        .positional("dir", "trace directory (holds trace.jsonl)")
        .opt(
            "format",
            "chrome",
            "output format: chrome (trace-event JSON — load the file in \
             https://ui.perfetto.dev or chrome://tracing)",
        )
        .opt_required("out", "write to this file instead of stdout");
    let a = unwrap_cli(spec.parse(args))?;
    let dir = a.pos("dir").ok_or("missing <dir>")?;
    match a.get("format").unwrap_or("chrome") {
        "chrome" => {}
        other => return Err(format!("--format must be 'chrome', got '{other}'")),
    }
    let trace = read_trace_dir(dir)?;
    let doc = memento::obs::trace::chrome_trace(trace.header.as_ref(), &trace.spans);
    match a.get("out") {
        Some(path) => {
            memento::util::fs::atomic_write(Path::new(path), doc.pretty().as_bytes())
                .map_err(|e| e.to_string())?;
            eprintln!("chrome trace written to {path}");
        }
        None => println!("{doc}"),
    }
    Ok(())
}

fn cmd_report(args: &[String]) -> Result<(), String> {
    let spec = CliSpec::new("memento report", "pivot saved results into a table")
        .opt_required("results", "results JSON written by `memento run --out`")
        .opt("rows", "dataset", "pivot row parameter")
        .opt("cols", "model", "pivot column parameter")
        .opt("metric", "accuracy", "metric field");
    let a = unwrap_cli(spec.parse(args))?;
    let path = a.get("results").ok_or("missing --results")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let doc = parse(&text).map_err(|e| e.to_string())?;
    let results = result_set_from_json(&doc)?;
    println!(
        "{}",
        results
            .pivot(
                a.get("rows").unwrap_or("dataset"),
                a.get("cols").unwrap_or("model"),
                a.get("metric").unwrap_or("accuracy"),
            )
            .render()
    );
    println!("{}", results.summary());
    Ok(())
}

/// Rebuilds a ResultSet from the JSON written by `run --out` (used by
/// `report`; tolerates missing optional fields).
fn result_set_from_json(doc: &Json) -> Result<ResultSet, String> {
    use memento::config::value::ParamValue;
    use memento::coordinator::results::{TaskOutcome, TaskStatus};
    use memento::coordinator::task::{TaskId, TaskSpec};
    let arr = doc.as_arr().ok_or("results file must be a JSON array")?;
    let mut outcomes = Vec::with_capacity(arr.len());
    for (i, entry) in arr.iter().enumerate() {
        let params_obj = entry
            .get("params")
            .and_then(|j| j.as_obj())
            .ok_or_else(|| format!("entry {i} missing params"))?;
        let params: Vec<(String, ParamValue)> = params_obj
            .iter()
            .filter_map(|(k, v)| ParamValue::from_json(v).map(|pv| (k.clone(), pv)))
            .collect();
        let status_ok = entry.get("status").and_then(|j| j.as_str()) == Some("success");
        outcomes.push(TaskOutcome {
            spec: TaskSpec { params, index: i, exp: None },
            id: TaskId(
                entry
                    .get("id")
                    .and_then(|j| j.as_str())
                    .unwrap_or("")
                    .to_string(),
            ),
            status: if status_ok { TaskStatus::Success } else { TaskStatus::Failed },
            value: entry.get("value").cloned(),
            failure: None,
            duration_secs: entry
                .get("duration_secs")
                .and_then(|j| j.as_f64())
                .unwrap_or(0.0),
            from_cache: entry
                .get("from_cache")
                .and_then(|j| j.as_bool())
                .unwrap_or(false),
            attempts: entry.get("attempts").and_then(|j| j.as_i64()).unwrap_or(1) as u32,
        });
    }
    Ok(ResultSet::new(outcomes))
}

// Referenced to keep the import alive in both run/resume paths.
#[allow(dead_code)]
fn _checkpoint_type_check(dir: &Path) -> bool {
    CheckpointStore::exists(dir)
}
