//! Configuration-matrix validation.
//!
//! Catching specification mistakes *before* expansion is a big part of the
//! paper's "reliable experiments" story: a typo in an exclude rule silently
//! skipping nothing (or everything) is exactly the class of error that used
//! to require "tedious debugging". Every rule here turns one such mistake
//! into an immediate, named error.

use crate::config::matrix::ConfigMatrix;
use crate::coordinator::error::MementoError;

/// Validates a matrix. Returns the first violated rule.
///
/// Rules:
/// 1. at least one parameter;
/// 2. parameter names are unique and non-empty;
/// 3. every domain is non-empty;
/// 4. no duplicate values within a domain (duplicate tasks would collide in
///    the cache and silently halve the experiment set);
/// 5. every exclude key names a declared parameter;
/// 6. every exclude value is a member of that parameter's domain;
/// 7. exclude rules are non-empty (an empty rule would match — and skip —
///    every combination).
pub fn validate(m: &ConfigMatrix) -> Result<(), MementoError> {
    if m.parameters.is_empty() {
        return Err(MementoError::config("matrix declares no parameters"));
    }
    let mut seen = std::collections::BTreeSet::new();
    for (name, domain) in &m.parameters {
        if name.is_empty() {
            return Err(MementoError::config("parameter with empty name"));
        }
        if !seen.insert(name.clone()) {
            return Err(MementoError::config(format!(
                "duplicate parameter name '{name}'"
            )));
        }
        if domain.is_empty() {
            return Err(MementoError::config(format!(
                "parameter '{name}' has an empty domain"
            )));
        }
        for (i, v) in domain.iter().enumerate() {
            for w in &domain[i + 1..] {
                if v == w {
                    return Err(MementoError::config(format!(
                        "parameter '{name}' has duplicate value '{v}'"
                    )));
                }
            }
        }
    }
    for (ri, rule) in m.exclude.iter().enumerate() {
        if rule.is_empty() {
            return Err(MementoError::config(format!(
                "exclude rule #{ri} is empty (would exclude every task)"
            )));
        }
        for (key, val) in rule {
            let domain = m.domain(key).ok_or_else(|| {
                MementoError::config(format!(
                    "exclude rule #{ri} references unknown parameter '{key}'"
                ))
            })?;
            if !domain.iter().any(|d| d == val) {
                return Err(MementoError::config(format!(
                    "exclude rule #{ri}: value '{val}' is not in the domain of '{key}'"
                )));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use crate::config::matrix::ConfigMatrix;
    use crate::config::value::{pv_int, pv_str};

    fn base() -> crate::config::matrix::MatrixBuilder {
        ConfigMatrix::builder()
            .param("a", vec![pv_int(1), pv_int(2)])
            .param("b", vec![pv_str("x")])
    }

    #[test]
    fn valid_matrix_passes() {
        assert!(base().build().is_ok());
    }

    #[test]
    fn no_parameters_fails() {
        let err = ConfigMatrix::builder().build().unwrap_err();
        assert!(err.to_string().contains("no parameters"), "{err}");
    }

    #[test]
    fn duplicate_param_name_fails() {
        let err = base().param("a", vec![pv_int(9)]).build().unwrap_err();
        assert!(err.to_string().contains("duplicate parameter"), "{err}");
    }

    #[test]
    fn empty_domain_fails() {
        let err = base().param("c", vec![]).build().unwrap_err();
        assert!(err.to_string().contains("empty domain"), "{err}");
    }

    #[test]
    fn duplicate_domain_value_fails() {
        let err = base()
            .param("c", vec![pv_int(1), pv_int(1)])
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("duplicate value"), "{err}");
    }

    #[test]
    fn exclude_unknown_key_fails() {
        let err = base()
            .exclude(vec![("nope", pv_int(1))])
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("unknown parameter"), "{err}");
    }

    #[test]
    fn exclude_value_outside_domain_fails() {
        let err = base().exclude(vec![("a", pv_int(99))]).build().unwrap_err();
        assert!(err.to_string().contains("not in the domain"), "{err}");
    }

    #[test]
    fn empty_exclude_rule_fails() {
        // The generic `exclude` needs a key type even for an empty rule.
        let err = base()
            .exclude(Vec::<(&str, crate::config::value::ParamValue)>::new())
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("is empty"), "{err}");
    }
}
