//! Sweep helpers beyond the plain cartesian product.
//!
//! The paper's matrix is a full grid; real campaigns often want more:
//!
//! - [`random_subset`] — random search: a seeded uniform sample of the
//!   expansion (without replacement), as one would do when the full grid
//!   is too large;
//! - [`zip_params`] — paired parameters that move together (e.g.
//!   `(dataset, epochs)` tuned per dataset) instead of crossing;
//! - [`union`] — concatenate the task lists of several matrices
//!   (heterogeneous campaign stages under one run);
//! - [`with_overrides`] — a matrix with some parameters pinned (ablation
//!   slices of a bigger grid).

use crate::config::matrix::ConfigMatrix;
use crate::config::value::ParamValue;
use crate::coordinator::error::MementoError;
use crate::coordinator::expand;
use crate::coordinator::task::TaskSpec;
use crate::util::rng::Rng;

/// Uniformly samples `k` distinct tasks from the matrix expansion
/// (deterministic in `seed`). `k` larger than the expansion returns all.
///
/// Reservoir sampling over the lazy [`expand::Expansion`] stream: memory
/// is O(k) no matter how large the grid — exactly the "full grid is too
/// large" situation random search exists for. (Time is still one pass
/// over the included combinations; that's inherent to uniform sampling.)
pub fn random_subset(matrix: &ConfigMatrix, k: usize, seed: u64) -> Vec<TaskSpec> {
    let mut reservoir: Vec<TaskSpec> = Vec::new();
    if k == 0 {
        return reservoir;
    }
    let mut rng = Rng::new(seed);
    for (seen, t) in expand::Expansion::new(matrix).enumerate() {
        if reservoir.len() < k {
            reservoir.push(t);
        } else {
            let j = rng.below(seen + 1);
            if j < k {
                reservoir[j] = t;
            }
        }
    }
    // Re-index so downstream ordering is stable.
    reservoir.sort_by_key(|t| t.index);
    for (i, t) in reservoir.iter_mut().enumerate() {
        t.index = i;
    }
    reservoir
}

/// Builds tasks where the listed parameters are *zipped* (paired by
/// position) rather than crossed; remaining parameters still cross.
///
/// All zipped domains must have equal length.
pub fn zip_params(
    matrix: &ConfigMatrix,
    zipped: &[&str],
) -> Result<Vec<TaskSpec>, MementoError> {
    if zipped.is_empty() {
        return Ok(expand::Expansion::new(matrix).collect());
    }
    let mut zip_len = None;
    for name in zipped {
        let d = matrix.domain(name).ok_or_else(|| {
            MementoError::config(format!("zip_params: unknown parameter '{name}'"))
        })?;
        match zip_len {
            None => zip_len = Some(d.len()),
            Some(l) if l != d.len() => {
                return Err(MementoError::config(format!(
                    "zip_params: '{name}' has {} values, expected {l}",
                    d.len()
                )))
            }
            _ => {}
        }
    }
    let zip_len = zip_len.unwrap();

    // Cross the non-zipped parameters, then splice each zip row in.
    let rest: Vec<(String, Vec<ParamValue>)> = matrix
        .parameters
        .iter()
        .filter(|(n, _)| !zipped.contains(&n.as_str()))
        .cloned()
        .collect();
    let rest_matrix = ConfigMatrix {
        parameters: if rest.is_empty() {
            vec![("__unit".to_string(), vec![ParamValue::Int(0)])]
        } else {
            rest
        },
        settings: matrix.settings.clone(),
        exclude: Vec::new(),
    };

    let mut out = Vec::new();
    let mut index = 0;
    for rest_spec in expand::Expansion::new(&rest_matrix) {
        for zi in 0..zip_len {
            let mut params: Vec<(String, ParamValue)> = matrix
                .parameters
                .iter()
                .map(|(name, domain)| {
                    if zipped.contains(&name.as_str()) {
                        (name.clone(), domain[zi].clone())
                    } else {
                        (
                            name.clone(),
                            rest_spec.get(name).expect("crossed param").clone(),
                        )
                    }
                })
                .collect();
            params.retain(|(n, _)| n != "__unit");
            let spec = TaskSpec { params, index, exp: None };
            if !expand::is_excluded(&spec, &matrix.exclude) {
                out.push(spec);
                index += 1;
            }
        }
    }
    Ok(out)
}

/// Concatenates the expansions of several matrices, re-indexing.
pub fn union(matrices: &[&ConfigMatrix]) -> Vec<TaskSpec> {
    let mut out = Vec::new();
    for m in matrices {
        for mut t in expand::Expansion::new(*m) {
            t.index = out.len();
            out.push(t);
        }
    }
    out
}

/// A copy of the matrix with some parameters pinned to a single value
/// (ablation slice). Pinned names must exist; values must be in-domain.
pub fn with_overrides(
    matrix: &ConfigMatrix,
    pins: &[(&str, ParamValue)],
) -> Result<ConfigMatrix, MementoError> {
    let mut m = matrix.clone();
    for (name, value) in pins {
        let slot = m
            .parameters
            .iter_mut()
            .find(|(n, _)| n == name)
            .ok_or_else(|| {
                MementoError::config(format!("override: unknown parameter '{name}'"))
            })?;
        if !slot.1.iter().any(|v| v == value) {
            return Err(MementoError::config(format!(
                "override: value '{value}' not in the domain of '{name}'"
            )));
        }
        slot.1 = vec![value.clone()];
    }
    crate::config::validate::validate(&m)?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::{pv_int, pv_str};

    fn matrix() -> ConfigMatrix {
        ConfigMatrix::builder()
            .param("a", vec![pv_int(0), pv_int(1), pv_int(2)])
            .param("b", vec![pv_str("x"), pv_str("y")])
            .build()
            .unwrap()
    }

    #[test]
    fn random_subset_is_distinct_and_seeded() {
        let m = matrix();
        let s1 = random_subset(&m, 4, 7);
        let s2 = random_subset(&m, 4, 7);
        assert_eq!(s1.len(), 4);
        assert_eq!(
            s1.iter().map(|t| t.label()).collect::<Vec<_>>(),
            s2.iter().map(|t| t.label()).collect::<Vec<_>>()
        );
        let mut labels: Vec<_> = s1.iter().map(|t| t.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 4, "distinct");
        // k > expansion returns everything
        assert_eq!(random_subset(&m, 100, 0).len(), 6);
        // indices contiguous
        for (i, t) in random_subset(&m, 4, 9).iter().enumerate() {
            assert_eq!(t.index, i);
        }
    }

    #[test]
    fn zip_pairs_instead_of_crossing() {
        let m = ConfigMatrix::builder()
            .param("dataset", vec![pv_str("wine"), pv_str("digits")])
            .param("epochs", vec![pv_int(10), pv_int(50)])
            .param("model", vec![pv_str("SVC"), pv_str("MLP")])
            .build()
            .unwrap();
        let tasks = zip_params(&m, &["dataset", "epochs"]).unwrap();
        // 2 zip rows × 2 models = 4 (instead of 8 crossed)
        assert_eq!(tasks.len(), 4);
        for t in &tasks {
            let ds = t.get("dataset").unwrap().as_str().unwrap();
            let ep = t.get("epochs").unwrap().as_i64().unwrap();
            assert!(
                (ds == "wine" && ep == 10) || (ds == "digits" && ep == 50),
                "unzipped pair {ds}/{ep}"
            );
        }
    }

    #[test]
    fn zip_respects_excludes_and_validates() {
        let m = ConfigMatrix::builder()
            .param("a", vec![pv_int(0), pv_int(1)])
            .param("b", vec![pv_int(0), pv_int(1)])
            .exclude(vec![("a", pv_int(0))])
            .build()
            .unwrap();
        let tasks = zip_params(&m, &["a", "b"]).unwrap();
        assert_eq!(tasks.len(), 1); // (1,1) only; (0,0) excluded
        // length mismatch errors
        let m = ConfigMatrix::builder()
            .param("a", vec![pv_int(0), pv_int(1)])
            .param("b", vec![pv_int(0)])
            .build()
            .unwrap();
        assert!(zip_params(&m, &["a", "b"]).is_err());
        assert!(zip_params(&m, &["nope"]).is_err());
    }

    #[test]
    fn zip_all_params() {
        let m = ConfigMatrix::builder()
            .param("a", vec![pv_int(0), pv_int(1)])
            .param("b", vec![pv_int(5), pv_int(6)])
            .build()
            .unwrap();
        let tasks = zip_params(&m, &["a", "b"]).unwrap();
        assert_eq!(tasks.len(), 2);
        assert_eq!(tasks[0].get("b"), Some(&pv_int(5)));
    }

    #[test]
    fn union_concatenates_and_reindexes() {
        let m1 = matrix();
        let m2 = ConfigMatrix::builder()
            .param("c", vec![pv_int(9)])
            .build()
            .unwrap();
        let all = union(&[&m1, &m2]);
        assert_eq!(all.len(), 7);
        for (i, t) in all.iter().enumerate() {
            assert_eq!(t.index, i);
        }
        assert!(all[6].get("c").is_some());
    }

    #[test]
    fn overrides_pin_parameters() {
        let m = matrix();
        let sliced = with_overrides(&m, &[("a", pv_int(1))]).unwrap();
        assert_eq!(sliced.raw_count(), 2);
        assert!(with_overrides(&m, &[("zzz", pv_int(0))]).is_err());
        assert!(with_overrides(&m, &[("a", pv_int(99))]).is_err());
    }
}
