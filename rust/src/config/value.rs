//! Parameter values for the configuration matrix.
//!
//! In the paper's Python API a parameter value can be any object (a dataset
//! loader, an estimator class, …). In Rust the matrix stores *descriptions*
//! — typed scalar values, usually strings naming a component — and the
//! experiment function maps them to concrete behaviour. This keeps tasks
//! serializable, hashable, and cache-stable.

use crate::util::json::Json;
use std::cmp::Ordering;
use std::fmt;

/// A single parameter value in the configuration matrix.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamValue {
    /// A string (usually naming a component: a model, a dataset).
    Str(String),
    /// An integer.
    Int(i64),
    /// A float (non-integer numbers only; see [`ParamValue::from_json`]).
    Float(f64),
    /// A boolean.
    Bool(bool),
}

/// Shorthand string constructor (used heavily in configs and tests).
pub fn pv_str(s: impl Into<String>) -> ParamValue {
    ParamValue::Str(s.into())
}
/// Shorthand integer constructor.
pub fn pv_int(i: i64) -> ParamValue {
    ParamValue::Int(i)
}
/// Shorthand float constructor.
pub fn pv_f64(f: f64) -> ParamValue {
    ParamValue::Float(f)
}
/// Shorthand boolean constructor.
pub fn pv_bool(b: bool) -> ParamValue {
    ParamValue::Bool(b)
}

impl ParamValue {
    /// Converts to JSON for persistence/hashing.
    pub fn to_json(&self) -> Json {
        match self {
            ParamValue::Str(s) => Json::Str(s.clone()),
            ParamValue::Int(i) => Json::int(*i),
            ParamValue::Float(f) => Json::Num(*f),
            ParamValue::Bool(b) => Json::Bool(*b),
        }
    }

    /// Parses from JSON. Integer-valued numbers become [`ParamValue::Int`]
    /// so that `1` and `1.0` are the same value (matching JSON semantics and
    /// keeping hashes stable across writers).
    pub fn from_json(j: &Json) -> Option<ParamValue> {
        match j {
            Json::Str(s) => Some(ParamValue::Str(s.clone())),
            Json::Bool(b) => Some(ParamValue::Bool(*b)),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    Some(ParamValue::Int(*n as i64))
                } else {
                    Some(ParamValue::Float(*n))
                }
            }
            _ => None,
        }
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            ParamValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The integer value, if this is an `Int`.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            ParamValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// The numeric value (`Float`, or `Int` coerced).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            ParamValue::Float(f) => Some(*f),
            ParamValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            ParamValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Total order for deterministic sorting of domains/excludes.
    pub fn cmp_total(&self, other: &ParamValue) -> Ordering {
        fn rank(v: &ParamValue) -> u8 {
            match v {
                ParamValue::Bool(_) => 0,
                ParamValue::Int(_) => 1,
                ParamValue::Float(_) => 2,
                ParamValue::Str(_) => 3,
            }
        }
        match (self, other) {
            (ParamValue::Bool(a), ParamValue::Bool(b)) => a.cmp(b),
            (ParamValue::Int(a), ParamValue::Int(b)) => a.cmp(b),
            (ParamValue::Float(a), ParamValue::Float(b)) => {
                a.partial_cmp(b).unwrap_or(Ordering::Equal)
            }
            (ParamValue::Str(a), ParamValue::Str(b)) => a.cmp(b),
            (a, b) => rank(a).cmp(&rank(b)),
        }
    }
}

impl fmt::Display for ParamValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParamValue::Str(s) => write!(f, "{s}"),
            ParamValue::Int(i) => write!(f, "{i}"),
            ParamValue::Float(x) => write!(f, "{x}"),
            ParamValue::Bool(b) => write!(f, "{b}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn json_roundtrip() {
        let vals = [pv_str("abc"), pv_int(-4), pv_f64(2.5), pv_bool(true)];
        for v in vals {
            let j = v.to_json();
            assert_eq!(ParamValue::from_json(&j), Some(v));
        }
    }

    #[test]
    fn integral_floats_normalize_to_int() {
        let j = parse("3.0").unwrap();
        assert_eq!(ParamValue::from_json(&j), Some(pv_int(3)));
        let j = parse("3.5").unwrap();
        assert_eq!(ParamValue::from_json(&j), Some(pv_f64(3.5)));
    }

    #[test]
    fn arrays_and_objects_rejected() {
        assert_eq!(ParamValue::from_json(&parse("[1]").unwrap()), None);
        assert_eq!(ParamValue::from_json(&parse("{}").unwrap()), None);
        assert_eq!(ParamValue::from_json(&Json::Null), None);
    }

    #[test]
    fn accessors() {
        assert_eq!(pv_str("x").as_str(), Some("x"));
        assert_eq!(pv_int(7).as_i64(), Some(7));
        assert_eq!(pv_int(7).as_f64(), Some(7.0));
        assert_eq!(pv_f64(1.5).as_f64(), Some(1.5));
        assert_eq!(pv_bool(true).as_bool(), Some(true));
        assert_eq!(pv_str("x").as_i64(), None);
    }

    #[test]
    fn total_order_is_total() {
        let mut vals = vec![pv_str("b"), pv_int(2), pv_bool(false), pv_f64(0.5), pv_str("a"), pv_int(1)];
        vals.sort_by(|a, b| a.cmp_total(b));
        // bools < ints < floats < strings
        assert_eq!(vals[0], pv_bool(false));
        assert_eq!(vals[1], pv_int(1));
        assert_eq!(vals[2], pv_int(2));
        assert_eq!(vals[3], pv_f64(0.5));
        assert_eq!(vals[4], pv_str("a"));
        assert_eq!(vals[5], pv_str("b"));
    }

    #[test]
    fn display_is_plain() {
        assert_eq!(pv_str("RandomForest").to_string(), "RandomForest");
        assert_eq!(pv_int(5).to_string(), "5");
    }
}
