//! The configuration matrix — the core abstraction of the paper (§3).
//!
//! A [`ConfigMatrix`] declares, exactly like the paper's Python dict:
//! - `parameters`: named, ordered domains of [`ParamValue`]s whose cartesian
//!   product defines the experiment set,
//! - `settings`: constants visible to every task (the paper: "removing the
//!   need to access global constants"),
//! - `exclude`: partial assignments; any product combination matching *all*
//!   pairs of an exclude rule is skipped ("a lookup table to skip any
//!   unwanted combinations").

use crate::config::value::ParamValue;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One exclusion rule: a partial parameter assignment.
pub type ExcludeRule = BTreeMap<String, ParamValue>;

/// A fully specified experiment configuration matrix.
#[derive(Debug, Clone)]
pub struct ConfigMatrix {
    /// Parameter domains in declaration order (order affects task ordering,
    /// not task identity).
    pub parameters: Vec<(String, Vec<ParamValue>)>,
    /// Run-wide constants accessible from every task.
    pub settings: BTreeMap<String, Json>,
    /// Combinations to skip.
    pub exclude: Vec<ExcludeRule>,
}

impl ConfigMatrix {
    /// A fresh [`MatrixBuilder`].
    pub fn builder() -> MatrixBuilder {
        MatrixBuilder::default()
    }

    /// Number of combinations before exclusion (the paper's 3×2×3×3 = 54).
    pub fn raw_count(&self) -> usize {
        self.parameters.iter().map(|(_, d)| d.len()).product()
    }

    /// Domain of a parameter by name.
    pub fn domain(&self, name: &str) -> Option<&[ParamValue]> {
        self.parameters
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| d.as_slice())
    }

    /// Parameter names in declaration order.
    pub fn param_names(&self) -> Vec<&str> {
        self.parameters.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Serializes to the paper's JSON shape.
    pub fn to_json(&self) -> Json {
        let params = Json::Obj(
            self.parameters
                .iter()
                .map(|(n, d)| {
                    (
                        n.clone(),
                        Json::Arr(d.iter().map(|v| v.to_json()).collect()),
                    )
                })
                .collect(),
        );
        let excl = Json::Arr(
            self.exclude
                .iter()
                .map(|rule| {
                    Json::Obj(rule.iter().map(|(k, v)| (k.clone(), v.to_json())).collect())
                })
                .collect(),
        );
        Json::obj(vec![
            ("parameters", params),
            ("settings", Json::Obj(self.settings.clone())),
            ("exclude", excl),
        ])
    }

    /// A stable fingerprint of the matrix (canonical JSON). Checkpoint
    /// manifests store this to refuse resuming against a *different* matrix.
    pub fn fingerprint(&self) -> String {
        crate::coordinator::task::sha256_hex(self.to_json().canonical().as_bytes())
    }
}

/// Fluent builder for [`ConfigMatrix`].
#[derive(Debug, Default)]
pub struct MatrixBuilder {
    parameters: Vec<(String, Vec<ParamValue>)>,
    settings: BTreeMap<String, Json>,
    exclude: Vec<ExcludeRule>,
}

impl MatrixBuilder {
    /// Adds a parameter with its domain of values.
    pub fn param(mut self, name: impl Into<String>, domain: Vec<ParamValue>) -> Self {
        self.parameters.push((name.into(), domain));
        self
    }

    /// Adds a run-wide setting.
    pub fn setting(mut self, name: impl Into<String>, value: Json) -> Self {
        self.settings.insert(name.into(), value);
        self
    }

    /// Adds an exclusion rule from (name, value) pairs. Accepts any
    /// iterable of pairs whose keys convert into `String` — the same
    /// signature family as [`MatrixBuilder::param`]/[`MatrixBuilder::setting`]
    /// — so `vec![("a", pv_int(1))]`, arrays, and owned `String` keys all
    /// work without adapter code.
    pub fn exclude<K: Into<String>>(
        mut self,
        pairs: impl IntoIterator<Item = (K, ParamValue)>,
    ) -> Self {
        self.exclude.push(
            pairs
                .into_iter()
                .map(|(k, v)| (k.into(), v))
                .collect(),
        );
        self
    }

    /// Validates and constructs the matrix (see [`crate::config::validate`]).
    pub fn build(self) -> Result<ConfigMatrix, crate::coordinator::error::MementoError> {
        let m = ConfigMatrix {
            parameters: self.parameters,
            settings: self.settings,
            exclude: self.exclude,
        };
        crate::config::validate::validate(&m)?;
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::{pv_int, pv_str};

    fn paper_matrix() -> ConfigMatrix {
        // The §3 example: 3 datasets × 2 FE × 3 preprocessing × 3 models.
        ConfigMatrix::builder()
            .param(
                "dataset",
                vec![pv_str("digits"), pv_str("wine"), pv_str("breast_cancer")],
            )
            .param(
                "feature_engineering",
                vec![pv_str("DummyImputer"), pv_str("SimpleImputer")],
            )
            .param(
                "preprocessing",
                vec![
                    pv_str("DummyPreprocessor"),
                    pv_str("MinMaxScaler"),
                    pv_str("StandardScaler"),
                ],
            )
            .param(
                "model",
                vec![pv_str("AdaBoost"), pv_str("RandomForest"), pv_str("SVC")],
            )
            .setting("n_fold", Json::int(5))
            .exclude(vec![
                ("dataset", pv_str("digits")),
                ("feature_engineering", pv_str("SimpleImputer")),
            ])
            .build()
            .unwrap()
    }

    #[test]
    fn raw_count_matches_paper() {
        assert_eq!(paper_matrix().raw_count(), 54);
    }

    #[test]
    fn domain_lookup() {
        let m = paper_matrix();
        assert_eq!(m.domain("model").unwrap().len(), 3);
        assert!(m.domain("nope").is_none());
        assert_eq!(
            m.param_names(),
            vec!["dataset", "feature_engineering", "preprocessing", "model"]
        );
    }

    #[test]
    fn fingerprint_stable_and_sensitive() {
        let a = paper_matrix();
        let b = paper_matrix();
        assert_eq!(a.fingerprint(), b.fingerprint());
        let c = ConfigMatrix::builder()
            .param("dataset", vec![pv_str("digits")])
            .build()
            .unwrap();
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn json_roundtrip_via_loader() {
        let m = paper_matrix();
        let text = m.to_json().pretty();
        let back = crate::config::loader::from_str(&text).unwrap();
        assert_eq!(back.raw_count(), 54);
        assert_eq!(back.fingerprint(), m.fingerprint());
        assert_eq!(back.settings.get("n_fold").unwrap().as_i64(), Some(5));
        assert_eq!(back.exclude.len(), 1);
    }

    #[test]
    fn builder_settings_and_excludes() {
        let m = ConfigMatrix::builder()
            .param("a", vec![pv_int(1), pv_int(2)])
            .param("b", vec![pv_int(3)])
            .setting("k", Json::str("v"))
            .exclude(vec![("a", pv_int(1))])
            .build()
            .unwrap();
        assert_eq!(m.raw_count(), 2);
        assert_eq!(m.settings["k"].as_str(), Some("v"));
        assert_eq!(m.exclude[0]["a"], pv_int(1));
    }
}
