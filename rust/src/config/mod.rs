//! Configuration matrices: typed parameter values, the matrix itself,
//! JSON loading, and validation (the paper's §3 `config_matrix`).

pub mod loader;
pub mod sweep;
pub mod matrix;
pub mod validate;
pub mod value;
