//! Loading configuration matrices from JSON files.
//!
//! The on-disk shape mirrors the paper's Python dict exactly:
//!
//! ```json
//! {
//!   "parameters": {
//!     "dataset": ["digits", "wine", "breast_cancer"],
//!     "model": ["AdaBoost", "RandomForest", "SVC"]
//!   },
//!   "settings": {"n_fold": 5},
//!   "exclude": [{"dataset": "digits", "feature_engineering": "SimpleImputer"}]
//! }
//! ```
//!
//! `settings` and `exclude` are optional. Parameter order follows sorted key
//! order (JSON objects are unordered); ordering affects only the order tasks
//! are *generated* in, never task identity or hashing.

use crate::config::matrix::{ConfigMatrix, ExcludeRule};
use crate::config::value::ParamValue;
use crate::coordinator::error::MementoError;
use crate::util::json::{parse, Json};
use std::path::Path;

/// Parses a matrix from JSON text and validates it.
pub fn from_str(text: &str) -> Result<ConfigMatrix, MementoError> {
    let doc = parse(text).map_err(|e| MementoError::config(format!("invalid JSON: {e}")))?;
    from_json(&doc)
}

/// Reads and parses a matrix from a file.
pub fn from_file(path: &Path) -> Result<ConfigMatrix, MementoError> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        MementoError::config(format!("cannot read config '{}': {e}", path.display()))
    })?;
    from_str(&text)
}

/// Converts a parsed JSON document into a validated matrix.
pub fn from_json(doc: &Json) -> Result<ConfigMatrix, MementoError> {
    let params_obj = doc
        .get("parameters")
        .and_then(|p| p.as_obj())
        .ok_or_else(|| MementoError::config("config must have an object field 'parameters'"))?;

    let mut parameters = Vec::with_capacity(params_obj.len());
    for (name, domain_json) in params_obj {
        let arr = domain_json.as_arr().ok_or_else(|| {
            MementoError::config(format!("parameter '{name}' must map to an array"))
        })?;
        let mut domain = Vec::with_capacity(arr.len());
        for (i, v) in arr.iter().enumerate() {
            let pv = ParamValue::from_json(v).ok_or_else(|| {
                MementoError::config(format!(
                    "parameter '{name}' value #{i} must be a scalar (string/number/bool)"
                ))
            })?;
            domain.push(pv);
        }
        parameters.push((name.clone(), domain));
    }

    let settings = match doc.get("settings") {
        None => Default::default(),
        Some(Json::Obj(o)) => o.clone(),
        Some(_) => return Err(MementoError::config("'settings' must be an object")),
    };

    let exclude = match doc.get("exclude") {
        None => Vec::new(),
        Some(Json::Arr(rules)) => {
            let mut out: Vec<ExcludeRule> = Vec::with_capacity(rules.len());
            for (ri, rule) in rules.iter().enumerate() {
                let obj = rule.as_obj().ok_or_else(|| {
                    MementoError::config(format!("exclude rule #{ri} must be an object"))
                })?;
                let mut r = ExcludeRule::new();
                for (k, v) in obj {
                    let pv = ParamValue::from_json(v).ok_or_else(|| {
                        MementoError::config(format!(
                            "exclude rule #{ri} key '{k}' must map to a scalar"
                        ))
                    })?;
                    r.insert(k.clone(), pv);
                }
                out.push(r);
            }
            out
        }
        Some(_) => return Err(MementoError::config("'exclude' must be an array")),
    };

    let m = ConfigMatrix { parameters, settings, exclude };
    crate::config::validate::validate(&m)?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::{pv_int, pv_str};

    const PAPER_JSON: &str = r#"{
        "parameters": {
            "dataset": ["digits", "wine", "breast_cancer"],
            "feature_engineering": ["DummyImputer", "SimpleImputer"],
            "preprocessing": ["DummyPreprocessor", "MinMaxScaler", "StandardScaler"],
            "model": ["AdaBoost", "RandomForest", "SVC"]
        },
        "settings": {"n_fold": 5},
        "exclude": [{"dataset": "digits", "feature_engineering": "SimpleImputer"}]
    }"#;

    #[test]
    fn loads_paper_config() {
        let m = from_str(PAPER_JSON).unwrap();
        assert_eq!(m.raw_count(), 54);
        assert_eq!(m.settings["n_fold"].as_i64(), Some(5));
        assert_eq!(m.exclude.len(), 1);
        assert_eq!(m.exclude[0]["dataset"], pv_str("digits"));
    }

    #[test]
    fn settings_and_exclude_optional() {
        let m = from_str(r#"{"parameters": {"x": [1, 2, 3]}}"#).unwrap();
        assert_eq!(m.raw_count(), 3);
        assert!(m.settings.is_empty());
        assert!(m.exclude.is_empty());
        assert_eq!(m.domain("x").unwrap()[0], pv_int(1));
    }

    #[test]
    fn mixed_scalar_domains() {
        let m = from_str(r#"{"parameters": {"lr": [0.1, 0.01], "deep": [true, false], "n": [1, 2]}}"#)
            .unwrap();
        assert_eq!(m.raw_count(), 8);
    }

    #[test]
    fn missing_parameters_field() {
        let e = from_str(r#"{"settings": {}}"#).unwrap_err();
        assert!(e.to_string().contains("parameters"), "{e}");
    }

    #[test]
    fn non_array_domain() {
        let e = from_str(r#"{"parameters": {"x": 5}}"#).unwrap_err();
        assert!(e.to_string().contains("must map to an array"), "{e}");
    }

    #[test]
    fn non_scalar_domain_value() {
        let e = from_str(r#"{"parameters": {"x": [[1]]}}"#).unwrap_err();
        assert!(e.to_string().contains("scalar"), "{e}");
    }

    #[test]
    fn bad_exclude_shapes() {
        let e = from_str(r#"{"parameters": {"x": [1]}, "exclude": [5]}"#).unwrap_err();
        assert!(e.to_string().contains("must be an object"), "{e}");
        let e = from_str(r#"{"parameters": {"x": [1]}, "exclude": {}}"#).unwrap_err();
        assert!(e.to_string().contains("must be an array"), "{e}");
    }

    #[test]
    fn invalid_json_reports_position() {
        let e = from_str("{nope}").unwrap_err();
        assert!(e.to_string().contains("invalid JSON"), "{e}");
    }

    #[test]
    fn validation_applies_on_load() {
        // exclude referencing unknown key must fail through the loader too
        let e = from_str(r#"{"parameters": {"x": [1]}, "exclude": [{"y": 1}]}"#).unwrap_err();
        assert!(e.to_string().contains("unknown parameter"), "{e}");
    }

    #[test]
    fn file_roundtrip() {
        let td = crate::util::fs::TempDir::new("loader").unwrap();
        let p = td.join("config.json");
        crate::util::fs::atomic_write(&p, PAPER_JSON.as_bytes()).unwrap();
        let m = from_file(&p).unwrap();
        assert_eq!(m.raw_count(), 54);
        assert!(from_file(&td.join("missing.json")).is_err());
    }
}
