//! # Memento-RS
//!
//! A Rust + JAX + Pallas reproduction of **"Memento: Facilitating
//! Effortless, Efficient, and Reliable ML Experiments"** (Pullar-Strecker
//! et al., ECML PKDD 2023).
//!
//! Memento turns a *configuration matrix* — the cartesian product of
//! parameter choices, minus exclusion rules — into a set of isolated,
//! hashed experiment tasks that are scheduled across a worker pool,
//! cached, checkpointed, retried, and reported on.
//!
//! ```no_run
//! use memento::prelude::*;
//!
//! let matrix = ConfigMatrix::builder()
//!     .param("x", vec![pv_int(1), pv_int(2)])
//!     .param("y", vec![pv_str("a"), pv_str("b")])
//!     .build()
//!     .unwrap();
//! let results = Memento::new(|task| Ok(Json::int(task.param_i64("x")? * 10)))
//!     .workers(4)
//!     .run(&matrix)
//!     .unwrap();
//! assert_eq!(results.len(), 4);
//! ```
//!
//! Architecture (three layers, Python never on the request path):
//! - **L3** ([`coordinator`], [`config`]) — the orchestrator: this crate.
//! - **L2** — a JAX MLP train/predict graph, AOT-lowered to HLO text by
//!   `python/compile/aot.py` and executed through [`runtime`].
//! - **L1** — a Pallas fused-dense kernel inside that graph
//!   (`python/compile/kernels/dense.py`).
//!
//! The [`ml`] module provides the from-scratch learners/datasets used by the
//! paper's §3 demonstration grid, and [`experiments`] wires that grid up as
//! a reusable workload.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod experiments;
#[cfg(unix)]
pub mod ipc;
pub mod ml;
pub mod runtime;
pub mod testing;
pub mod util;

/// Convenience re-exports covering the public API surface.
pub mod prelude {
    pub use crate::config::matrix::{ConfigMatrix, MatrixBuilder};
    pub use crate::config::value::{pv_bool, pv_f64, pv_int, pv_str, ParamValue};
    pub use crate::coordinator::cache::ResultCache;
    pub use crate::coordinator::checkpoint::CheckpointStore;
    pub use crate::coordinator::error::{FailureKind, MementoError, TaskFailure};
    pub use crate::coordinator::memento::{Memento, RunOptions};
    pub use crate::coordinator::notify::{
        ConsoleNotificationProvider, FileNotificationProvider, MemoryNotificationProvider,
        NotificationProvider,
    };
    pub use crate::coordinator::results::{ResultSet, TaskOutcome, TaskStatus};
    pub use crate::coordinator::retry::RetryPolicy;
    pub use crate::coordinator::run::{ChannelPolicy, Run, RunEvent, RunSummary};
    pub use crate::coordinator::scheduler::ExecBackend;
    pub use crate::coordinator::task::{TaskContext, TaskId, TaskSpec};
    pub use crate::util::json::Json;
}
