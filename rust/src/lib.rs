//! # Memento-RS
//!
//! A Rust + JAX + Pallas reproduction of **"Memento: Facilitating
//! Effortless, Efficient, and Reliable ML Experiments"** (Pullar-Strecker
//! et al., ECML PKDD 2023), grown toward a production-scale
//! experiment-execution system.
//!
//! Memento turns a *configuration matrix* — the cartesian product of
//! parameter choices, minus exclusion rules — into a set of isolated,
//! hashed experiment tasks that are scheduled across a worker pool
//! (threads, isolated processes, or remote machines over TCP), cached,
//! checkpointed, retried, journaled, and reported on.
//!
//! ## Quickstart
//!
//! ```
//! use memento::prelude::*;
//!
//! let matrix = ConfigMatrix::builder()
//!     .param("x", vec![pv_int(1), pv_int(2)])
//!     .param("y", vec![pv_str("a"), pv_str("b")])
//!     .build()
//!     .unwrap();
//! let results = Memento::new(|task| Ok(Json::int(task.param_i64("x")? * 10)))
//!     .workers(4)
//!     .run(&matrix)
//!     .unwrap();
//! assert_eq!(results.len(), 4);
//! assert_eq!(results.n_failed(), 0);
//! ```
//!
//! The blocking [`prelude::Memento::run`] is one of two entry points; the
//! streaming `launch()` returns a live [`prelude::Run`] handle whose
//! typed events arrive as tasks finish. See `docs/ARCHITECTURE.md` at the
//! repository root for the end-to-end pipeline walkthrough (lazy
//! expansion → restore filter → scheduler/supervisor → cache/checkpoint/
//! journal → events) and the exactly-once accounting invariants.
//!
//! ## Architecture
//!
//! Three layers, Python never on the request path:
//! - **L3** ([`coordinator`], [`config`], [`store`], [`ipc`]) — the
//!   orchestrator: this crate.
//! - **L2** — a JAX MLP train/predict graph, AOT-lowered to HLO text by
//!   `python/compile/aot.py` and executed through [`runtime`].
//! - **L1** — a Pallas fused-dense kernel inside that graph
//!   (`python/compile/kernels/dense.py`).
//!
//! The [`ml`] module provides the from-scratch learners/datasets used by the
//! paper's §3 demonstration grid, and [`experiments`] wires that grid (and
//! the `echo` smoke workload) into a named experiment registry so a task —
//! not a process — decides what it runs. Everything is `std`-only: JSON, SHA-256, the
//! thread pool, the CLI parser, the bench harness, and the IPC/TCP layer
//! live under [`util`]/[`bench`] instead of external crates.

#![warn(missing_docs)]

pub mod bench;
pub mod config;
pub mod coordinator;
#[cfg(unix)]
pub mod daemon;
pub mod experiments;
#[cfg(unix)]
pub mod ipc;
pub mod ml;
pub mod obs;
pub mod runtime;
pub mod store;
pub mod testing;
pub mod util;

/// Convenience re-exports covering the public API surface.
pub mod prelude {
    pub use crate::config::matrix::{ConfigMatrix, MatrixBuilder};
    pub use crate::config::value::{pv_bool, pv_f64, pv_int, pv_str, ParamValue};
    pub use crate::coordinator::cache::ResultCache;
    pub use crate::coordinator::checkpoint::CheckpointStore;
    pub use crate::coordinator::error::{FailureKind, MementoError, TaskFailure};
    pub use crate::coordinator::memento::{Memento, RunOptions};
    pub use crate::coordinator::notify::{
        ConsoleNotificationProvider, FileNotificationProvider, MemoryNotificationProvider,
        NotificationProvider,
    };
    pub use crate::coordinator::results::{ResultSet, TaskOutcome, TaskStatus};
    pub use crate::coordinator::retry::RetryPolicy;
    pub use crate::coordinator::run::{ChannelPolicy, Run, RunEvent, RunSummary};
    pub use crate::coordinator::scheduler::ExecBackend;
    pub use crate::coordinator::task::{ExpRef, TaskContext, TaskId, TaskSpec};
    pub use crate::experiments::registry::{ExpEntry, Registry};
    pub use crate::obs::snapshot::{MetricsSnapshot, WorkerStat};
    pub use crate::obs::trace::{SpanEvent, SpanState, TraceSummary, Tracer};
    pub use crate::store::query::{parse_predicates, Predicate, QueryOptions, QueryRow};
    pub use crate::store::{MigrationReport, ResultStore, StoreStats};
    pub use crate::util::codec::WireFormat;
    pub use crate::util::json::Json;
}
