//! Classification metrics: accuracy, confusion matrix, macro precision/
//! recall/F1.

/// Fraction of predictions equal to the truth.
pub fn accuracy(truth: &[usize], pred: &[usize]) -> f64 {
    assert_eq!(truth.len(), pred.len(), "length mismatch");
    if truth.is_empty() {
        return 0.0;
    }
    let correct = truth.iter().zip(pred).filter(|(t, p)| t == p).count();
    correct as f64 / truth.len() as f64
}

/// `confusion[t][p]` = count of true class `t` predicted as `p`.
pub fn confusion_matrix(truth: &[usize], pred: &[usize], n_classes: usize) -> Vec<Vec<usize>> {
    assert_eq!(truth.len(), pred.len(), "length mismatch");
    let mut m = vec![vec![0usize; n_classes]; n_classes];
    for (&t, &p) in truth.iter().zip(pred) {
        m[t][p] += 1;
    }
    m
}

/// Per-class (precision, recall, f1); absent classes get zeros.
pub fn per_class_prf(truth: &[usize], pred: &[usize], n_classes: usize) -> Vec<(f64, f64, f64)> {
    let m = confusion_matrix(truth, pred, n_classes);
    (0..n_classes)
        .map(|c| {
            let tp = m[c][c] as f64;
            let fp: f64 = (0..n_classes).filter(|&t| t != c).map(|t| m[t][c] as f64).sum();
            let fn_: f64 = (0..n_classes).filter(|&p| p != c).map(|p| m[c][p] as f64).sum();
            let precision = if tp + fp > 0.0 { tp / (tp + fp) } else { 0.0 };
            let recall = if tp + fn_ > 0.0 { tp / (tp + fn_) } else { 0.0 };
            let f1 = if precision + recall > 0.0 {
                2.0 * precision * recall / (precision + recall)
            } else {
                0.0
            };
            (precision, recall, f1)
        })
        .collect()
}

/// Unweighted mean of per-class F1.
pub fn macro_f1(truth: &[usize], pred: &[usize], n_classes: usize) -> f64 {
    let prf = per_class_prf(truth, pred, n_classes);
    prf.iter().map(|(_, _, f1)| f1).sum::<f64>() / n_classes as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[0, 1, 2, 1], &[0, 1, 1, 1]), 0.75);
        assert_eq!(accuracy(&[], &[]), 0.0);
        assert_eq!(accuracy(&[1, 1], &[1, 1]), 1.0);
    }

    #[test]
    fn confusion_shape_and_counts() {
        let m = confusion_matrix(&[0, 0, 1, 2], &[0, 1, 1, 2], 3);
        assert_eq!(m[0][0], 1);
        assert_eq!(m[0][1], 1);
        assert_eq!(m[1][1], 1);
        assert_eq!(m[2][2], 1);
        let total: usize = m.iter().flatten().sum();
        assert_eq!(total, 4);
    }

    #[test]
    fn perfect_prediction_prf() {
        let prf = per_class_prf(&[0, 1, 2], &[0, 1, 2], 3);
        for (p, r, f1) in prf {
            assert_eq!((p, r, f1), (1.0, 1.0, 1.0));
        }
        assert_eq!(macro_f1(&[0, 1, 2], &[0, 1, 2], 3), 1.0);
    }

    #[test]
    fn absent_class_zeroed() {
        // class 2 never appears in truth or pred
        let prf = per_class_prf(&[0, 1], &[0, 1], 3);
        assert_eq!(prf[2], (0.0, 0.0, 0.0));
        let f1 = macro_f1(&[0, 1], &[0, 1], 3);
        assert!((f1 - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_prf_values() {
        // truth: [0,0,0,1,1], pred: [0,0,1,1,0]
        // class0: tp=2 fp=1 fn=1 → p=2/3 r=2/3 f1=2/3
        // class1: tp=1 fp=1 fn=1 → p=1/2 r=1/2 f1=1/2
        let prf = per_class_prf(&[0, 0, 0, 1, 1], &[0, 0, 1, 1, 0], 2);
        assert!((prf[0].0 - 2.0 / 3.0).abs() < 1e-12);
        assert!((prf[0].2 - 2.0 / 3.0).abs() < 1e-12);
        assert!((prf[1].2 - 0.5).abs() < 1e-12);
        assert!((macro_f1(&[0, 0, 0, 1, 1], &[0, 0, 1, 1, 0], 2) - (2.0 / 3.0 + 0.5) / 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        accuracy(&[0], &[0, 1]);
    }
}
