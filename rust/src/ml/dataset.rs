//! Synthetic stand-ins for the paper's three sklearn datasets.
//!
//! The offline image has no sklearn data files, so `load_digits`,
//! `load_wine`, and `load_breast_cancer` are replaced by deterministic
//! generators that reproduce each dataset's **shape, class structure, and
//! rough difficulty ordering** (digits: many classes, high dimension;
//! wine: 3 well-separated classes; breast_cancer: 2 classes, mild overlap).
//! The orchestrator-level experiments only observe task cost and metric
//! structure, which these generators preserve (see DESIGN.md
//! §Substitutions).
//!
//! Generation model: each class `c` gets a mean vector drawn from a seeded
//! RNG; rows are `mean + sigma * N(0, I)` with a low-rank distortion to
//! correlate features; a fixed fraction of cells is then masked to NaN so
//! the imputation stage has real work to do.

use crate::ml::data::Dataset;
use crate::util::rng::Rng;

/// Parameters of the blob generator.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    /// Dataset name.
    pub name: &'static str,
    /// Rows to generate.
    pub n_rows: usize,
    /// Feature columns to generate.
    pub n_cols: usize,
    /// Distinct classes.
    pub n_classes: usize,
    /// Class-mean spread (bigger = easier).
    pub separation: f64,
    /// Within-class noise.
    pub sigma: f64,
    /// Fraction of cells masked to NaN.
    pub missing_rate: f64,
    /// Generator seed (fully deterministic).
    pub seed: u64,
}

/// Generates a blob dataset per the spec. Deterministic in the seed.
pub fn generate(spec: &SynthSpec) -> Dataset {
    let mut rng = Rng::new(spec.seed);

    // Class means on a scaled hypercube-ish lattice.
    let means: Vec<Vec<f64>> = (0..spec.n_classes)
        .map(|_| {
            (0..spec.n_cols)
                .map(|_| rng.normal() * spec.separation)
                .collect()
        })
        .collect();

    // Low-rank mixing matrix to correlate features (rank 4).
    let rank = 4.min(spec.n_cols);
    let mix: Vec<Vec<f64>> = (0..rank)
        .map(|_| (0..spec.n_cols).map(|_| rng.normal() * 0.3).collect())
        .collect();

    let mut x = Vec::with_capacity(spec.n_rows * spec.n_cols);
    let mut y = Vec::with_capacity(spec.n_rows);
    for i in 0..spec.n_rows {
        let class = i % spec.n_classes; // balanced classes
        let mean = &means[class];
        // latent low-rank factors
        let factors: Vec<f64> = (0..rank).map(|_| rng.normal()).collect();
        for c in 0..spec.n_cols {
            let corr: f64 = (0..rank).map(|r| factors[r] * mix[r][c]).sum();
            let v = mean[c] + spec.sigma * (rng.normal() + corr);
            x.push(v as f32);
        }
        y.push(class);
    }

    // Shuffle rows (keeping x/y aligned) so folds are class-mixed.
    let mut order: Vec<usize> = (0..spec.n_rows).collect();
    rng.shuffle(&mut order);
    let mut ds = Dataset::new(spec.name, x, spec.n_rows, spec.n_cols, y, spec.n_classes);
    ds = ds.subset(&order);

    // Inject missingness.
    if spec.missing_rate > 0.0 {
        let total = ds.n_rows * ds.n_cols;
        let n_missing = (total as f64 * spec.missing_rate) as usize;
        for _ in 0..n_missing {
            let r = rng.below(ds.n_rows);
            let c = rng.below(ds.n_cols);
            ds.row_mut(r)[c] = f32::NAN;
        }
    }
    ds
}

/// `load_digits` stand-in: 1797×64, 10 classes (8×8 grayscale digits).
pub fn digits(seed: u64) -> Dataset {
    generate(&SynthSpec {
        name: "digits",
        n_rows: 1797,
        n_cols: 64,
        n_classes: 10,
        separation: 1.6,
        sigma: 1.0,
        missing_rate: 0.01,
        seed: seed ^ 0xD161_7500,
    })
}

/// `load_wine` stand-in: 178×13, 3 classes, well-separated.
pub fn wine(seed: u64) -> Dataset {
    generate(&SynthSpec {
        name: "wine",
        n_rows: 178,
        n_cols: 13,
        n_classes: 3,
        separation: 2.2,
        sigma: 1.0,
        missing_rate: 0.02,
        seed: seed ^ 0x0B1E_D0C7,
    })
}

/// `load_breast_cancer` stand-in: 569×30, 2 classes, mild overlap.
pub fn breast_cancer(seed: u64) -> Dataset {
    generate(&SynthSpec {
        name: "breast_cancer",
        n_rows: 569,
        n_cols: 30,
        n_classes: 2,
        separation: 1.4,
        sigma: 1.0,
        missing_rate: 0.02,
        seed: seed ^ 0xBC56_9000,
    })
}

/// Loads a dataset by the name used in the §3 config matrix.
pub fn load_by_name(name: &str, seed: u64) -> Option<Dataset> {
    match name {
        "digits" => Some(digits(seed)),
        "wine" => Some(wine(seed)),
        "breast_cancer" => Some(breast_cancer(seed)),
        "toy" => Some(toy(seed)),
        _ => None,
    }
}

/// A tiny fast dataset for unit tests and quickstarts (120×8, 3 classes).
pub fn toy(seed: u64) -> Dataset {
    generate(&SynthSpec {
        name: "toy",
        n_rows: 120,
        n_cols: 8,
        n_classes: 3,
        separation: 2.5,
        sigma: 0.8,
        missing_rate: 0.02,
        seed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_sklearn() {
        let d = digits(0);
        assert_eq!((d.n_rows, d.n_cols, d.n_classes), (1797, 64, 10));
        let w = wine(0);
        assert_eq!((w.n_rows, w.n_cols, w.n_classes), (178, 13, 3));
        let b = breast_cancer(0);
        assert_eq!((b.n_rows, b.n_cols, b.n_classes), (569, 30, 2));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = wine(7);
        let b = wine(7);
        // Compare ignoring NaN positions equality (NaN != NaN).
        assert_eq!(a.y, b.y);
        for (x, y) in a.x.iter().zip(&b.x) {
            assert!(x.to_bits() == y.to_bits());
        }
        let c = wine(8);
        assert!(a.x.iter().zip(&c.x).any(|(x, y)| x.to_bits() != y.to_bits()));
    }

    #[test]
    fn classes_are_balanced() {
        let d = toy(1);
        let counts = d.class_counts();
        assert_eq!(counts.iter().sum::<usize>(), 120);
        for c in counts {
            assert!((35..=45).contains(&c), "unbalanced: {c}");
        }
    }

    #[test]
    fn missingness_injected() {
        let d = wine(3);
        let frac = d.missing_count() as f64 / (d.n_rows * d.n_cols) as f64;
        assert!(frac > 0.005 && frac < 0.05, "missing frac {frac}");
    }

    #[test]
    fn load_by_name_roundtrip() {
        assert!(load_by_name("digits", 0).is_some());
        assert!(load_by_name("wine", 0).is_some());
        assert!(load_by_name("breast_cancer", 0).is_some());
        assert!(load_by_name("mnist", 0).is_none());
    }

    #[test]
    fn classes_are_separable_by_centroid_rule() {
        // Sanity: a nearest-centroid classifier (fit on means ignoring NaN)
        // must beat chance by a wide margin on the "easy" datasets —
        // otherwise the grid's accuracy numbers would be meaningless.
        let d = wine(0);
        let mut centroids = vec![vec![0f64; d.n_cols]; d.n_classes];
        let mut counts = vec![vec![0usize; d.n_cols]; d.n_classes];
        for r in 0..d.n_rows {
            let c = d.y[r];
            for (j, &v) in d.row(r).iter().enumerate() {
                if !v.is_nan() {
                    centroids[c][j] += v as f64;
                    counts[c][j] += 1;
                }
            }
        }
        for c in 0..d.n_classes {
            for j in 0..d.n_cols {
                if counts[c][j] > 0 {
                    centroids[c][j] /= counts[c][j] as f64;
                }
            }
        }
        let mut correct = 0;
        for r in 0..d.n_rows {
            let mut best = (f64::INFINITY, 0usize);
            for (c, cen) in centroids.iter().enumerate() {
                let dist: f64 = d
                    .row(r)
                    .iter()
                    .enumerate()
                    .filter(|(_, v)| !v.is_nan())
                    .map(|(j, &v)| (v as f64 - cen[j]).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == d.y[r] {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.n_rows as f64;
        assert!(acc > 0.8, "wine centroid accuracy {acc} too low");
    }
}
