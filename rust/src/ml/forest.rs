//! Random forest: bootstrap-aggregated CART trees with per-split feature
//! subsampling (√d by default) and majority voting.

use crate::ml::data::Dataset;
use crate::ml::tree::{Classifier, DecisionTree, TreeParams};
use crate::util::rng::Rng;

/// Forest hyperparameters.
#[derive(Debug, Clone)]
pub struct ForestParams {
    /// Trees in the ensemble.
    pub n_trees: usize,
    /// Maximum depth per tree.
    pub max_depth: usize,
    /// Per-split feature candidates; `None` = ⌈√d⌉.
    pub max_features: Option<usize>,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams { n_trees: 30, max_depth: 12, max_features: None }
    }
}

/// A fitted random forest.
#[derive(Debug, Clone)]
pub struct RandomForest {
    params: ForestParams,
    trees: Vec<DecisionTree>,
    n_classes: usize,
}

impl RandomForest {
    /// An unfitted forest with the given hyperparameters.
    pub fn new(params: ForestParams) -> Self {
        RandomForest { params, trees: Vec::new(), n_classes: 0 }
    }

    /// Trees actually fitted.
    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

impl Classifier for RandomForest {
    fn fit(&mut self, train: &Dataset, rng: &mut Rng) {
        self.n_classes = train.n_classes;
        self.trees.clear();
        let max_features = self
            .params
            .max_features
            .unwrap_or_else(|| (train.n_cols as f64).sqrt().ceil() as usize)
            .clamp(1, train.n_cols);
        for t in 0..self.params.n_trees {
            let mut tree_rng = rng.fork(t as u64);
            // Bootstrap sample (with replacement).
            let sample: Vec<usize> =
                (0..train.n_rows).map(|_| tree_rng.below(train.n_rows)).collect();
            let boot = train.subset(&sample);
            let mut tree = DecisionTree::new(TreeParams {
                max_depth: self.params.max_depth,
                min_samples_split: 2,
                max_features: Some(max_features),
            });
            tree.fit(&boot, &mut tree_rng);
            self.trees.push(tree);
        }
    }

    fn predict(&self, ds: &Dataset) -> Vec<usize> {
        assert!(!self.trees.is_empty(), "predict before fit");
        let mut votes = vec![vec![0usize; self.n_classes]; ds.n_rows];
        for tree in &self.trees {
            for (r, p) in tree.predict(ds).into_iter().enumerate() {
                votes[r][p] += 1;
            }
        }
        votes
            .into_iter()
            .map(|v| {
                v.iter()
                    .enumerate()
                    .max_by_key(|(_, &c)| c)
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::dataset::toy;
    use crate::ml::impute::{DummyImputer, Transformer};
    use crate::ml::metrics::accuracy;
    use crate::ml::split::train_test_indices;

    fn clean_toy() -> Dataset {
        let mut ds = toy(0);
        DummyImputer.transform(&mut ds);
        ds
    }

    #[test]
    fn fits_and_generalizes() {
        let ds = clean_toy();
        let mut rng = Rng::new(5);
        let (train_idx, test_idx) = train_test_indices(&ds, 0.3, &mut rng);
        let train = ds.subset(&train_idx);
        let test = ds.subset(&test_idx);
        let mut rf = RandomForest::new(ForestParams { n_trees: 20, ..Default::default() });
        rf.fit(&train, &mut rng);
        let acc = accuracy(&test.y, &rf.predict(&test));
        assert!(acc > 0.8, "test accuracy {acc}");
        assert_eq!(rf.n_trees(), 20);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = clean_toy();
        let fit = |seed| {
            let mut rf = RandomForest::new(ForestParams { n_trees: 5, ..Default::default() });
            rf.fit(&ds, &mut Rng::new(seed));
            rf.predict(&ds)
        };
        assert_eq!(fit(3), fit(3));
    }

    #[test]
    fn more_trees_not_worse_on_train() {
        let ds = clean_toy();
        let acc_of = |n_trees| {
            let mut rf = RandomForest::new(ForestParams { n_trees, ..Default::default() });
            rf.fit(&ds, &mut Rng::new(7));
            accuracy(&ds.y, &rf.predict(&ds))
        };
        let small = acc_of(1);
        let big = acc_of(25);
        assert!(big >= small - 0.05, "1 tree {small} vs 25 trees {big}");
    }

    #[test]
    #[should_panic(expected = "predict before fit")]
    fn predict_unfit_panics() {
        let rf = RandomForest::new(ForestParams::default());
        rf.predict(&clean_toy());
    }
}
