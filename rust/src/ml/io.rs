//! Dataset and result I/O: CSV import/export.
//!
//! Lets users run the grid over their *own* data — the paper's "compatible
//! with any type of machine-learning pipeline" claim. Conventions:
//! - last column is the label (string labels are mapped to class ids in
//!   first-appearance order),
//! - empty cells, `NA`, `na`, `nan`, `NaN`, and `?` parse as missing (NaN),
//! - all feature columns must parse as numbers otherwise.

use crate::ml::data::Dataset;
use crate::util::csv;
use std::fmt;

/// Dataset-loading errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoadError(pub String);

impl fmt::Display for LoadError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "dataset load error: {}", self.0)
    }
}

impl std::error::Error for LoadError {}

fn parse_cell(cell: &str) -> Result<f32, ()> {
    let t = cell.trim();
    if t.is_empty() || matches!(t, "NA" | "na" | "nan" | "NaN" | "?") {
        return Ok(f32::NAN);
    }
    t.parse::<f32>().map_err(|_| ())
}

/// Parses a dataset from CSV text (see module docs for conventions).
pub fn dataset_from_csv_str(
    name: &str,
    text: &str,
    has_header: bool,
) -> Result<Dataset, LoadError> {
    let table = csv::parse(text, has_header).map_err(|e| LoadError(e.to_string()))?;
    if table.rows.is_empty() {
        return Err(LoadError("no data rows".into()));
    }
    let width = table.rows[0].len();
    if width < 2 {
        return Err(LoadError("need at least one feature column + label".into()));
    }
    let n_cols = width - 1;
    let mut x = Vec::with_capacity(table.rows.len() * n_cols);
    let mut labels: Vec<String> = Vec::new();
    let mut y = Vec::with_capacity(table.rows.len());

    for (ri, row) in table.rows.iter().enumerate() {
        for (ci, cell) in row[..n_cols].iter().enumerate() {
            let v = parse_cell(cell).map_err(|_| {
                LoadError(format!("row {}, column {}: '{cell}' is not numeric", ri + 1, ci + 1))
            })?;
            x.push(v);
        }
        let label = row[n_cols].trim().to_string();
        if label.is_empty() {
            return Err(LoadError(format!("row {}: empty label", ri + 1)));
        }
        let class = match labels.iter().position(|l| l == &label) {
            Some(c) => c,
            None => {
                labels.push(label);
                labels.len() - 1
            }
        };
        y.push(class);
    }
    let n_rows = y.len();
    let n_classes = labels.len();
    Ok(Dataset::new(name, x, n_rows, n_cols, y, n_classes))
}

/// Reads a dataset from a CSV file.
pub fn dataset_from_csv_file(path: &std::path::Path, has_header: bool) -> Result<Dataset, LoadError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| LoadError(format!("read '{}': {e}", path.display())))?;
    let name = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("csv")
        .to_string();
    dataset_from_csv_str(&name, &text, has_header)
}

/// Exports a dataset back to CSV (labels as `class<k>`).
pub fn dataset_to_csv(ds: &Dataset) -> String {
    let mut table = csv::CsvTable {
        header: Some(
            (0..ds.n_cols)
                .map(|c| format!("f{c}"))
                .chain(std::iter::once("label".to_string()))
                .collect(),
        ),
        rows: Vec::with_capacity(ds.n_rows),
    };
    for r in 0..ds.n_rows {
        let mut row: Vec<String> = ds
            .row(r)
            .iter()
            .map(|v| if v.is_nan() { "NA".to_string() } else { format!("{v}") })
            .collect();
        row.push(format!("class{}", ds.y[r]));
        table.rows.push(row);
    }
    csv::write(&table)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
f0,f1,label
1.5,2.0,yes
3.0,NA,no
,4.5,yes
2.0,1.0,maybe
";

    #[test]
    fn loads_with_header_and_string_labels() {
        let ds = dataset_from_csv_str("s", SAMPLE, true).unwrap();
        assert_eq!((ds.n_rows, ds.n_cols, ds.n_classes), (4, 2, 3));
        assert_eq!(ds.y, vec![0, 1, 0, 2]); // first-appearance order
        assert_eq!(ds.missing_count(), 2);
        assert_eq!(ds.row(0), &[1.5, 2.0]);
    }

    #[test]
    fn numeric_labels_work() {
        let ds = dataset_from_csv_str("n", "1,0\n2,1\n3,0\n", false).unwrap();
        assert_eq!(ds.n_classes, 2);
        assert_eq!(ds.y, vec![0, 1, 0]);
    }

    #[test]
    fn bad_feature_cell_errors_with_position() {
        let e = dataset_from_csv_str("b", "1,x,yes\n", false).unwrap_err();
        assert!(e.0.contains("column 2"), "{e}");
    }

    #[test]
    fn too_narrow_errors() {
        assert!(dataset_from_csv_str("w", "1\n2\n", false).is_err());
        assert!(dataset_from_csv_str("e", "", false).is_err());
    }

    #[test]
    fn roundtrip_through_export() {
        let ds = dataset_from_csv_str("s", SAMPLE, true).unwrap();
        let text = dataset_to_csv(&ds);
        let back = dataset_from_csv_str("s2", &text, true).unwrap();
        assert_eq!(back.n_rows, ds.n_rows);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.missing_count(), ds.missing_count());
    }

    #[test]
    fn file_loading() {
        let td = crate::util::fs::TempDir::new("csvds").unwrap();
        let p = td.join("data.csv");
        crate::util::fs::atomic_write(&p, SAMPLE.as_bytes()).unwrap();
        let ds = dataset_from_csv_file(&p, true).unwrap();
        assert_eq!(ds.name, "data");
        assert!(dataset_from_csv_file(&td.join("nope.csv"), true).is_err());
    }

    #[test]
    fn csv_dataset_runs_through_the_pipeline() {
        // End-to-end: CSV → pipeline CV (small synthetic csv, 2 classes).
        let mut text = String::from("f0,f1,label\n");
        let mut rng = crate::util::rng::Rng::new(5);
        for i in 0..60 {
            let c = i % 2;
            let base = if c == 0 { -2.0 } else { 2.0 };
            text.push_str(&format!(
                "{},{},c{}\n",
                base + rng.normal() * 0.5,
                base + rng.normal() * 0.5,
                c
            ));
        }
        let ds = dataset_from_csv_str("synth", &text, true).unwrap();
        let scores = crate::ml::pipeline::cross_validate_named(
            &ds,
            "SimpleImputer",
            "StandardScaler",
            "LogisticRegression",
            3,
            &mut crate::util::rng::Rng::new(0),
        )
        .unwrap();
        assert!(scores.mean_accuracy > 0.9, "{}", scores.mean_accuracy);
    }
}
