//! From-scratch ML substrate for the paper's §3 demonstration grid.
//!
//! The paper's example varies datasets (`digits`/`wine`/`breast_cancer`),
//! imputers, scalers, and models (`AdaBoost`/`RandomForest`/`SVC`); every
//! one of those components is implemented here (see DESIGN.md
//! §Substitutions for how the synthetic datasets stand in for sklearn's).

pub mod adaboost;
pub mod data;
pub mod dataset;
pub mod forest;
pub mod impute;
pub mod io;
pub mod knn;
pub mod logistic;
pub mod metrics;
pub mod naive_bayes;
pub mod pipeline;
pub mod scale;
pub mod split;
pub mod svc;
pub mod tree;
