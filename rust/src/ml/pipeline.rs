//! Pipeline composition: impute → scale → model, evaluated by stratified
//! k-fold cross-validation. This is the body of the paper's `exp_func`.
//!
//! All fit-time statistics (imputation means, scaler ranges) are learned on
//! each fold's training split only — the leakage discipline sklearn's
//! `Pipeline` enforces, reimplemented here.

use crate::ml::adaboost::{AdaBoost, AdaBoostParams};
use crate::ml::data::Dataset;
use crate::ml::forest::{ForestParams, RandomForest};
use crate::ml::impute::imputer_by_name;
use crate::ml::knn::{Knn, KnnParams};
use crate::ml::logistic::{LogisticParams, LogisticRegression};
use crate::ml::metrics::{accuracy, macro_f1};
use crate::ml::naive_bayes::{GaussianNb, GnbParams};
use crate::ml::scale::scaler_by_name;
use crate::ml::split::stratified_kfold;
use crate::ml::svc::{LinearSvc, SvcParams};
use crate::ml::tree::{Classifier, DecisionTree, TreeParams};
use crate::util::rng::Rng;

/// Constructs one of the grid models by config-matrix name (the §3 trio
/// plus the extension families).
pub fn model_by_name(name: &str) -> Option<Box<dyn Classifier>> {
    match name {
        "AdaBoost" => Some(Box::new(AdaBoost::new(AdaBoostParams::default()))),
        "RandomForest" => Some(Box::new(RandomForest::new(ForestParams::default()))),
        "SVC" => Some(Box::new(LinearSvc::new(SvcParams::default()))),
        "DecisionTree" => Some(Box::new(DecisionTree::new(TreeParams::default()))),
        "KNN" => Some(Box::new(Knn::new(KnnParams::default()))),
        "GaussianNB" => Some(Box::new(GaussianNb::new(GnbParams::default()))),
        "LogisticRegression" => {
            Some(Box::new(LogisticRegression::new(LogisticParams::default())))
        }
        _ => None,
    }
}

/// Names accepted by [`model_by_name`] (used by config validation helpers).
pub const MODEL_NAMES: &[&str] = &[
    "AdaBoost",
    "RandomForest",
    "SVC",
    "DecisionTree",
    "KNN",
    "GaussianNB",
    "LogisticRegression",
];

/// Cross-validated pipeline scores.
#[derive(Debug, Clone)]
pub struct CvScores {
    /// Per-fold accuracy.
    pub fold_accuracy: Vec<f64>,
    /// Mean accuracy across folds.
    pub mean_accuracy: f64,
    /// Mean macro-averaged F1 across folds.
    pub mean_macro_f1: f64,
    /// Total rows evaluated across folds.
    pub n_eval: usize,
}

/// Errors from pipeline assembly (unknown component names).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnknownComponent(pub String);

impl std::fmt::Display for UnknownComponent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "unknown pipeline component '{}'", self.0)
    }
}

impl std::error::Error for UnknownComponent {}

/// Runs the full impute→scale→model pipeline with `k`-fold CV.
///
/// `model_factory` is called once per fold so every fold trains a fresh
/// model (no state leakage between folds).
pub fn cross_validate(
    ds: &Dataset,
    imputer_name: &str,
    scaler_name: &str,
    model_factory: &dyn Fn() -> Box<dyn Classifier>,
    k: usize,
    rng: &mut Rng,
) -> Result<CvScores, UnknownComponent> {
    // Validate component names up front (typo → immediate error).
    imputer_by_name(imputer_name).ok_or_else(|| UnknownComponent(imputer_name.into()))?;
    scaler_by_name(scaler_name).ok_or_else(|| UnknownComponent(scaler_name.into()))?;

    let folds = stratified_kfold(ds, k, rng);
    let mut fold_accuracy = Vec::with_capacity(k);
    let mut f1_sum = 0.0;
    let mut n_eval = 0;

    for (fi, fold) in folds.iter().enumerate() {
        let mut train = ds.subset(&fold.train);
        let mut test = ds.subset(&fold.test);

        let mut imputer = imputer_by_name(imputer_name).unwrap();
        imputer.fit(&train);
        imputer.transform(&mut train);
        imputer.transform(&mut test);

        let mut scaler = scaler_by_name(scaler_name).unwrap();
        scaler.fit(&train);
        scaler.transform(&mut train);
        scaler.transform(&mut test);

        let mut model = model_factory();
        let mut fold_rng = rng.fork(fi as u64);
        model.fit(&train, &mut fold_rng);
        let pred = model.predict(&test);

        fold_accuracy.push(accuracy(&test.y, &pred));
        f1_sum += macro_f1(&test.y, &pred, ds.n_classes);
        n_eval += test.n_rows;
    }

    let mean_accuracy = fold_accuracy.iter().sum::<f64>() / k as f64;
    Ok(CvScores {
        fold_accuracy,
        mean_accuracy,
        mean_macro_f1: f1_sum / k as f64,
        n_eval,
    })
}

/// Convenience: cross-validate with a named model.
pub fn cross_validate_named(
    ds: &Dataset,
    imputer_name: &str,
    scaler_name: &str,
    model_name: &str,
    k: usize,
    rng: &mut Rng,
) -> Result<CvScores, UnknownComponent> {
    model_by_name(model_name).ok_or_else(|| UnknownComponent(model_name.into()))?;
    let name = model_name.to_string();
    cross_validate(
        ds,
        imputer_name,
        scaler_name,
        &move || model_by_name(&name).unwrap(),
        k,
        rng,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::dataset::toy;

    #[test]
    fn full_pipeline_beats_chance() {
        let ds = toy(0);
        for model in ["AdaBoost", "RandomForest", "SVC"] {
            let scores = cross_validate_named(
                &ds,
                "SimpleImputer",
                "StandardScaler",
                model,
                3,
                &mut Rng::new(1),
            )
            .unwrap();
            assert_eq!(scores.fold_accuracy.len(), 3);
            assert_eq!(scores.n_eval, ds.n_rows);
            assert!(
                scores.mean_accuracy > 0.55,
                "{model} accuracy {}",
                scores.mean_accuracy
            );
            assert!(scores.mean_macro_f1 > 0.4, "{model} f1 {}", scores.mean_macro_f1);
        }
    }

    #[test]
    fn unknown_components_error() {
        let ds = toy(0);
        let e = cross_validate_named(&ds, "NopeImputer", "StandardScaler", "SVC", 2, &mut Rng::new(0))
            .unwrap_err();
        assert_eq!(e.0, "NopeImputer");
        let e = cross_validate_named(&ds, "SimpleImputer", "NopeScaler", "SVC", 2, &mut Rng::new(0))
            .unwrap_err();
        assert_eq!(e.0, "NopeScaler");
        let e = cross_validate_named(&ds, "SimpleImputer", "StandardScaler", "GPT", 2, &mut Rng::new(0))
            .unwrap_err();
        assert_eq!(e.0, "GPT");
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = toy(0);
        let run = |seed| {
            cross_validate_named(&ds, "SimpleImputer", "MinMaxScaler", "RandomForest", 3, &mut Rng::new(seed))
                .unwrap()
                .mean_accuracy
        };
        assert_eq!(run(2), run(2));
    }

    #[test]
    fn dummy_stages_run() {
        let ds = toy(0);
        let scores = cross_validate_named(
            &ds,
            "DummyImputer",
            "DummyPreprocessor",
            "DecisionTree",
            2,
            &mut Rng::new(3),
        )
        .unwrap();
        assert!(scores.mean_accuracy > 0.5, "{}", scores.mean_accuracy);
    }

    #[test]
    fn model_names_constant_is_consistent() {
        for name in MODEL_NAMES {
            assert!(model_by_name(name).is_some(), "{name}");
        }
    }
}
