//! Gaussian naive Bayes classifier (extension model family).
//!
//! Per-class, per-feature Gaussian likelihoods with variance smoothing
//! (sklearn's `var_smoothing` equivalent) and log-space evaluation.

use crate::ml::data::Dataset;
use crate::ml::tree::Classifier;
use crate::util::rng::Rng;

/// GNB hyperparameters.
#[derive(Debug, Clone)]
pub struct GnbParams {
    /// Fraction of the largest feature variance added to every variance.
    pub var_smoothing: f64,
}

impl Default for GnbParams {
    fn default() -> Self {
        GnbParams { var_smoothing: 1e-9 }
    }
}

/// A fitted Gaussian naive Bayes model.
#[derive(Debug, Clone, Default)]
pub struct GaussianNb {
    params: GnbParams,
    /// Per class: log prior.
    log_prior: Vec<f64>,
    /// Per class × feature: (mean, var).
    stats: Vec<Vec<(f64, f64)>>,
    n_classes: usize,
}

impl GaussianNb {
    /// An unfitted model with the given hyperparameters.
    pub fn new(params: GnbParams) -> GaussianNb {
        GaussianNb { params, ..Default::default() }
    }
}

impl Classifier for GaussianNb {
    fn fit(&mut self, train: &Dataset, _rng: &mut Rng) {
        self.n_classes = train.n_classes;
        let d = train.n_cols;
        let mut sums = vec![vec![0f64; d]; train.n_classes];
        let mut sq = vec![vec![0f64; d]; train.n_classes];
        let mut counts = vec![0usize; train.n_classes];
        for r in 0..train.n_rows {
            let c = train.y[r];
            counts[c] += 1;
            for (j, &v) in train.row(r).iter().enumerate() {
                sums[c][j] += v as f64;
                sq[c][j] += (v as f64) * (v as f64);
            }
        }
        let total = train.n_rows as f64;
        self.log_prior = counts
            .iter()
            .map(|&c| ((c.max(1)) as f64 / total).ln())
            .collect();
        // Global max variance for smoothing.
        let mut max_var = 0f64;
        self.stats = (0..train.n_classes)
            .map(|c| {
                (0..d)
                    .map(|j| {
                        let n = counts[c].max(1) as f64;
                        let mean = sums[c][j] / n;
                        let var = (sq[c][j] / n - mean * mean).max(0.0);
                        max_var = max_var.max(var);
                        (mean, var)
                    })
                    .collect()
            })
            .collect();
        let eps = self.params.var_smoothing * max_var.max(1e-12);
        for class_stats in &mut self.stats {
            for (_, var) in class_stats.iter_mut() {
                *var += eps;
                if *var <= 0.0 {
                    *var = 1e-12;
                }
            }
        }
    }

    fn predict(&self, ds: &Dataset) -> Vec<usize> {
        assert!(!self.stats.is_empty(), "predict before fit");
        (0..ds.n_rows)
            .map(|r| {
                let row = ds.row(r);
                (0..self.n_classes)
                    .map(|c| {
                        let mut ll = self.log_prior[c];
                        for (j, &v) in row.iter().enumerate() {
                            let (mean, var) = self.stats[c][j];
                            let diff = v as f64 - mean;
                            ll += -0.5 * ((2.0 * std::f64::consts::PI * var).ln()
                                + diff * diff / var);
                        }
                        (c, ll)
                    })
                    .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .map(|(c, _)| c)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::dataset::toy;
    use crate::ml::impute::{DummyImputer, Transformer};
    use crate::ml::metrics::accuracy;
    use crate::ml::split::train_test_indices;

    #[test]
    fn separates_gaussian_blobs() {
        let mut ds = toy(0);
        DummyImputer.transform(&mut ds);
        let mut rng = Rng::new(2);
        let (tr, te) = train_test_indices(&ds, 0.3, &mut rng);
        let mut gnb = GaussianNb::new(GnbParams::default());
        gnb.fit(&ds.subset(&tr), &mut rng);
        let test = ds.subset(&te);
        let acc = accuracy(&test.y, &gnb.predict(&test));
        assert!(acc > 0.85, "gnb accuracy {acc}");
    }

    #[test]
    fn log_priors_reflect_imbalance() {
        // 3 of class 0, 1 of class 1 → prior 0.75 vs 0.25
        let ds = Dataset::new(
            "imb",
            vec![0.0, 0.1, -0.1, 5.0],
            4,
            1,
            vec![0, 0, 0, 1],
            2,
        );
        let mut gnb = GaussianNb::new(GnbParams::default());
        gnb.fit(&ds, &mut Rng::new(0));
        assert!((gnb.log_prior[0] - 0.75f64.ln()).abs() < 1e-12);
        assert!((gnb.log_prior[1] - 0.25f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn constant_feature_does_not_nan() {
        let ds = Dataset::new(
            "const",
            vec![1.0, 1.0, 1.0, 1.0],
            4,
            1,
            vec![0, 0, 1, 1],
            2,
        );
        let mut gnb = GaussianNb::new(GnbParams::default());
        gnb.fit(&ds, &mut Rng::new(0));
        let pred = gnb.predict(&ds);
        assert_eq!(pred.len(), 4);
        assert!(pred.iter().all(|&p| p < 2));
    }

    #[test]
    fn deterministic() {
        let mut ds = toy(3);
        DummyImputer.transform(&mut ds);
        let run = || {
            let mut gnb = GaussianNb::new(GnbParams::default());
            gnb.fit(&ds, &mut Rng::new(0));
            gnb.predict(&ds)
        };
        assert_eq!(run(), run());
    }

    #[test]
    #[should_panic(expected = "predict before fit")]
    fn unfit_panics() {
        GaussianNb::new(GnbParams::default()).predict(&toy(0));
    }
}
