//! Feature scaling (the §3 grid's `preprocessing` stage).
//!
//! - [`DummyPreprocessor`] — identity;
//! - [`MinMaxScaler`] — maps each column's train-range to `[0, 1]`;
//! - [`StandardScaler`] — zero mean / unit variance per column.
//!
//! All statistics are learned on the training split only.

use crate::ml::data::Dataset;
use crate::ml::impute::Transformer;

/// Identity preprocessing.
#[derive(Debug, Default, Clone)]
pub struct DummyPreprocessor;

impl Transformer for DummyPreprocessor {
    fn fit(&mut self, _train: &Dataset) {}
    fn transform(&self, _ds: &mut Dataset) {}
}

/// Per-column `[min, max] → [0, 1]` scaling (constant columns map to 0).
#[derive(Debug, Default, Clone)]
pub struct MinMaxScaler {
    ranges: Vec<(f32, f32)>,
}

impl Transformer for MinMaxScaler {
    fn fit(&mut self, train: &Dataset) {
        self.ranges = train.column_min_max();
    }

    fn transform(&self, ds: &mut Dataset) {
        assert_eq!(self.ranges.len(), ds.n_cols, "MinMaxScaler column mismatch");
        for r in 0..ds.n_rows {
            let row = ds.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                let (lo, hi) = self.ranges[c];
                let span = hi - lo;
                if span > 0.0 {
                    *v = (*v - lo) / span;
                } else {
                    *v = 0.0;
                }
            }
        }
    }
}

/// Per-column standardization to zero mean / unit variance.
#[derive(Debug, Default, Clone)]
pub struct StandardScaler {
    stats: Vec<(f32, f32)>,
}

impl Transformer for StandardScaler {
    fn fit(&mut self, train: &Dataset) {
        self.stats = train.column_mean_std();
    }

    fn transform(&self, ds: &mut Dataset) {
        assert_eq!(self.stats.len(), ds.n_cols, "StandardScaler column mismatch");
        for r in 0..ds.n_rows {
            let row = ds.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                let (mean, std) = self.stats[c];
                *v = (*v - mean) / std;
            }
        }
    }
}

/// Constructs a preprocessor by its §3 config-matrix name.
pub fn scaler_by_name(name: &str) -> Option<Box<dyn Transformer>> {
    match name {
        "DummyPreprocessor" => Some(Box::new(DummyPreprocessor)),
        "MinMaxScaler" => Some(Box::new(MinMaxScaler::default())),
        "StandardScaler" => Some(Box::new(StandardScaler::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> Dataset {
        Dataset::new(
            "t",
            vec![
                0.0, 100.0, 5.0, //
                10.0, 200.0, 5.0, //
                5.0, 150.0, 5.0,
            ],
            3,
            3,
            vec![0, 1, 0],
            2,
        )
    }

    #[test]
    fn dummy_is_identity() {
        let mut d = ds();
        let orig = d.x.clone();
        let mut t = DummyPreprocessor;
        t.fit_transform(&mut d);
        assert_eq!(d.x, orig);
    }

    #[test]
    fn minmax_maps_to_unit_interval() {
        let mut d = ds();
        let mut t = MinMaxScaler::default();
        t.fit_transform(&mut d);
        assert_eq!(d.row(0)[0], 0.0);
        assert_eq!(d.row(1)[0], 1.0);
        assert!((d.row(2)[0] - 0.5).abs() < 1e-6);
        // constant column → 0
        assert_eq!(d.row(0)[2], 0.0);
        assert_eq!(d.row(2)[2], 0.0);
    }

    #[test]
    fn standard_zero_mean_unit_var() {
        let mut d = ds();
        let mut t = StandardScaler::default();
        t.fit_transform(&mut d);
        for c in 0..2 {
            let vals: Vec<f32> = (0..3).map(|r| d.row(r)[c]).collect();
            let mean: f32 = vals.iter().sum::<f32>() / 3.0;
            let var: f32 = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 3.0;
            assert!(mean.abs() < 1e-5, "col {c} mean {mean}");
            assert!((var - 1.0).abs() < 1e-4, "col {c} var {var}");
        }
    }

    #[test]
    fn train_stats_applied_to_test() {
        let train = ds();
        let mut t = MinMaxScaler::default();
        t.fit(&train);
        let mut test = Dataset::new("test", vec![20.0, 100.0, 5.0], 1, 3, vec![0], 2);
        t.transform(&mut test);
        assert_eq!(test.row(0)[0], 2.0, "out-of-range extrapolates");
    }

    #[test]
    fn by_name_constructs() {
        for n in ["DummyPreprocessor", "MinMaxScaler", "StandardScaler"] {
            assert!(scaler_by_name(n).is_some(), "{n}");
        }
        assert!(scaler_by_name("RobustScaler").is_none());
    }
}
