//! Train/test splitting and stratified k-fold cross-validation.

use crate::ml::data::Dataset;
use crate::util::rng::Rng;

/// Splits row indices into (train, test) with `test_frac` of rows held out,
/// stratified by class so both sides keep the class distribution.
pub fn train_test_indices(ds: &Dataset, test_frac: f64, rng: &mut Rng) -> (Vec<usize>, Vec<usize>) {
    assert!((0.0..1.0).contains(&test_frac), "test_frac in [0,1)");
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.n_classes];
    for (i, &c) in ds.y.iter().enumerate() {
        by_class[c].push(i);
    }
    let mut train = Vec::new();
    let mut test = Vec::new();
    for mut members in by_class {
        rng.shuffle(&mut members);
        let n_test = ((members.len() as f64) * test_frac).round() as usize;
        test.extend_from_slice(&members[..n_test]);
        train.extend_from_slice(&members[n_test..]);
    }
    rng.shuffle(&mut train);
    rng.shuffle(&mut test);
    (train, test)
}

/// One fold of a k-fold split: held-out test rows and the remaining train rows.
#[derive(Debug, Clone)]
pub struct Fold {
    /// Row indices to train on.
    pub train: Vec<usize>,
    /// Held-out row indices to evaluate on.
    pub test: Vec<usize>,
}

/// Stratified k-fold: each class's rows are dealt round-robin across folds,
/// so every fold keeps (approximately) the global class distribution.
pub fn stratified_kfold(ds: &Dataset, k: usize, rng: &mut Rng) -> Vec<Fold> {
    assert!(k >= 2, "k-fold needs k >= 2");
    let mut assignments = vec![0usize; ds.n_rows];
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.n_classes];
    for (i, &c) in ds.y.iter().enumerate() {
        by_class[c].push(i);
    }
    for mut members in by_class {
        rng.shuffle(&mut members);
        for (j, &row) in members.iter().enumerate() {
            assignments[row] = j % k;
        }
    }
    (0..k)
        .map(|fold| {
            let mut train = Vec::new();
            let mut test = Vec::new();
            for (row, &a) in assignments.iter().enumerate() {
                if a == fold {
                    test.push(row);
                } else {
                    train.push(row);
                }
            }
            Fold { train, test }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::dataset::toy;

    #[test]
    fn train_test_partition() {
        let ds = toy(0);
        let mut rng = Rng::new(1);
        let (train, test) = train_test_indices(&ds, 0.25, &mut rng);
        assert_eq!(train.len() + test.len(), ds.n_rows);
        let mut all: Vec<usize> = train.iter().chain(&test).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), ds.n_rows, "partition (no dup/loss)");
        // ~25% held out
        assert!((25..=35).contains(&test.len()), "test size {}", test.len());
    }

    #[test]
    fn train_test_is_stratified() {
        let ds = toy(0);
        let mut rng = Rng::new(2);
        let (_, test) = train_test_indices(&ds, 0.3, &mut rng);
        let mut counts = vec![0usize; ds.n_classes];
        for &i in &test {
            counts[ds.y[i]] += 1;
        }
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(max - min <= 2, "stratification off: {counts:?}");
    }

    #[test]
    fn kfold_covers_each_row_exactly_once_as_test() {
        let ds = toy(0);
        let mut rng = Rng::new(3);
        let folds = stratified_kfold(&ds, 5, &mut rng);
        assert_eq!(folds.len(), 5);
        let mut seen = vec![0usize; ds.n_rows];
        for f in &folds {
            assert_eq!(f.train.len() + f.test.len(), ds.n_rows);
            for &t in &f.test {
                seen[t] += 1;
            }
            // train/test disjoint
            for &t in &f.test {
                assert!(!f.train.contains(&t));
            }
        }
        assert!(seen.iter().all(|&s| s == 1), "each row tested exactly once");
    }

    #[test]
    fn kfold_is_stratified() {
        let ds = toy(0);
        let mut rng = Rng::new(4);
        for f in stratified_kfold(&ds, 4, &mut rng) {
            let mut counts = vec![0usize; ds.n_classes];
            for &i in &f.test {
                counts[ds.y[i]] += 1;
            }
            let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
            assert!(max - min <= 1, "fold stratification: {counts:?}");
        }
    }

    #[test]
    fn deterministic_given_rng_seed() {
        let ds = toy(0);
        let a = stratified_kfold(&ds, 3, &mut Rng::new(9));
        let b = stratified_kfold(&ds, 3, &mut Rng::new(9));
        for (fa, fb) in a.iter().zip(&b) {
            assert_eq!(fa.test, fb.test);
        }
    }

    #[test]
    #[should_panic(expected = "k >= 2")]
    fn k1_panics() {
        let ds = toy(0);
        stratified_kfold(&ds, 1, &mut Rng::new(0));
    }
}
