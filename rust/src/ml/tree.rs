//! CART decision trees (gini impurity), the base learner for the random
//! forest and — at depth 1 — the decision stumps AdaBoost boosts over.
//!
//! Supports sample weights (needed by SAMME AdaBoost) and per-split feature
//! subsampling (needed by the forest).

use crate::ml::data::Dataset;
use crate::util::rng::Rng;

/// Classifier interface shared by all §3 models.
pub trait Classifier: Send + Sync {
    /// Fits on a training set (rows must be NaN-free — impute first).
    fn fit(&mut self, train: &Dataset, rng: &mut Rng);
    /// Predicts class labels for every row.
    fn predict(&self, ds: &Dataset) -> Vec<usize>;
}

/// Tree hyperparameters.
#[derive(Debug, Clone)]
pub struct TreeParams {
    /// Maximum tree depth.
    pub max_depth: usize,
    /// Minimum rows a node needs to split further.
    pub min_samples_split: usize,
    /// Features examined per split; `None` = all.
    pub max_features: Option<usize>,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_depth: 12, min_samples_split: 2, max_features: None }
    }
}

#[derive(Debug, Clone)]
enum Node {
    Leaf {
        class: usize,
    },
    Split {
        feature: usize,
        threshold: f32,
        left: Box<Node>,
        right: Box<Node>,
    },
}

/// A fitted CART decision tree.
#[derive(Debug, Clone)]
pub struct DecisionTree {
    params: TreeParams,
    root: Option<Node>,
    n_classes: usize,
}

impl DecisionTree {
    /// An unfitted tree with the given hyperparameters.
    pub fn new(params: TreeParams) -> Self {
        DecisionTree { params, root: None, n_classes: 0 }
    }

    /// Fits with explicit per-row weights (uniform weights = plain CART).
    pub fn fit_weighted(&mut self, train: &Dataset, weights: &[f64], rng: &mut Rng) {
        assert_eq!(weights.len(), train.n_rows, "weight count");
        self.n_classes = train.n_classes;
        let rows: Vec<usize> = (0..train.n_rows).collect();
        self.root = Some(build_node(train, &rows, weights, &self.params, 0, rng));
    }

    fn predict_row(&self, row: &[f32]) -> usize {
        let mut node = self.root.as_ref().expect("predict before fit");
        loop {
            match node {
                Node::Leaf { class } => return *class,
                Node::Split { feature, threshold, left, right } => {
                    node = if row[*feature] <= *threshold { left } else { right };
                }
            }
        }
    }

    /// Tree depth (for tests / ablations).
    pub fn depth(&self) -> usize {
        fn d(n: &Node) -> usize {
            match n {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + d(left).max(d(right)),
            }
        }
        self.root.as_ref().map(|r| d(r)).unwrap_or(0)
    }
}

impl Classifier for DecisionTree {
    fn fit(&mut self, train: &Dataset, rng: &mut Rng) {
        let w = vec![1.0; train.n_rows];
        self.fit_weighted(train, &w, rng);
    }

    fn predict(&self, ds: &Dataset) -> Vec<usize> {
        (0..ds.n_rows).map(|r| self.predict_row(ds.row(r))).collect()
    }
}

fn weighted_class_counts(ds: &Dataset, rows: &[usize], weights: &[f64]) -> Vec<f64> {
    let mut counts = vec![0f64; ds.n_classes];
    for &r in rows {
        counts[ds.y[r]] += weights[r];
    }
    counts
}

fn majority(counts: &[f64]) -> usize {
    counts
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

fn gini(counts: &[f64]) -> f64 {
    let total: f64 = counts.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    1.0 - counts.iter().map(|c| (c / total) * (c / total)).sum::<f64>()
}

fn build_node(
    ds: &Dataset,
    rows: &[usize],
    weights: &[f64],
    params: &TreeParams,
    depth: usize,
    rng: &mut Rng,
) -> Node {
    let counts = weighted_class_counts(ds, rows, weights);
    let node_gini = gini(&counts);
    if depth >= params.max_depth
        || rows.len() < params.min_samples_split
        || node_gini <= 1e-12
    {
        return Node::Leaf { class: majority(&counts) };
    }

    // Candidate features (subsample for forests).
    let features: Vec<usize> = match params.max_features {
        Some(k) if k < ds.n_cols => rng.sample_indices(ds.n_cols, k),
        _ => (0..ds.n_cols).collect(),
    };

    let total_w: f64 = rows.iter().map(|&r| weights[r]).sum();
    let mut best: Option<(f64, usize, f32)> = None; // (impurity, feature, threshold)

    for &f in &features {
        // Sort rows by feature value; scan split points between distinct values.
        let mut order: Vec<usize> = rows.to_vec();
        order.sort_by(|&a, &b| {
            ds.row(a)[f].partial_cmp(&ds.row(b)[f]).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut left_counts = vec![0f64; ds.n_classes];
        let mut right_counts = counts.clone();
        let mut left_w = 0f64;
        for i in 0..order.len() - 1 {
            let r = order[i];
            left_counts[ds.y[r]] += weights[r];
            right_counts[ds.y[r]] -= weights[r];
            left_w += weights[r];
            let v = ds.row(r)[f];
            let v_next = ds.row(order[i + 1])[f];
            if v_next <= v {
                continue; // not a valid split point
            }
            let right_w = total_w - left_w;
            if left_w <= 0.0 || right_w <= 0.0 {
                continue;
            }
            let impurity =
                (left_w * gini(&left_counts) + right_w * gini(&right_counts)) / total_w;
            if best.map(|(b, _, _)| impurity < b - 1e-15).unwrap_or(true) {
                best = Some((impurity, f, (v + v_next) / 2.0));
            }
        }
    }

    match best {
        None => Node::Leaf { class: majority(&counts) },
        Some((impurity, feature, threshold)) => {
            if impurity >= node_gini - 1e-12 {
                return Node::Leaf { class: majority(&counts) };
            }
            let (left_rows, right_rows): (Vec<usize>, Vec<usize>) =
                rows.iter().partition(|&&r| ds.row(r)[feature] <= threshold);
            if left_rows.is_empty() || right_rows.is_empty() {
                return Node::Leaf { class: majority(&counts) };
            }
            Node::Split {
                feature,
                threshold,
                left: Box::new(build_node(ds, &left_rows, weights, params, depth + 1, rng)),
                right: Box::new(build_node(ds, &right_rows, weights, params, depth + 1, rng)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::dataset::toy;
    use crate::ml::impute::{DummyImputer, Transformer};
    use crate::ml::metrics::accuracy;

    fn clean_toy() -> Dataset {
        let mut ds = toy(0);
        DummyImputer.transform(&mut ds);
        ds
    }

    #[test]
    fn perfectly_separable_data_is_memorized() {
        // x < 0 → class 0, x >= 0 → class 1 on one feature.
        let x: Vec<f32> = vec![-2.0, -1.5, -1.0, 1.0, 1.5, 2.0];
        let ds = Dataset::new("sep", x, 6, 1, vec![0, 0, 0, 1, 1, 1], 2);
        let mut tree = DecisionTree::new(TreeParams::default());
        tree.fit(&ds, &mut Rng::new(0));
        assert_eq!(tree.predict(&ds), vec![0, 0, 0, 1, 1, 1]);
        assert_eq!(tree.depth(), 1);
    }

    #[test]
    fn fits_toy_dataset_well() {
        let ds = clean_toy();
        let mut tree = DecisionTree::new(TreeParams::default());
        tree.fit(&ds, &mut Rng::new(0));
        let acc = accuracy(&ds.y, &tree.predict(&ds));
        assert!(acc > 0.95, "train accuracy {acc}");
    }

    #[test]
    fn depth_limit_respected() {
        let ds = clean_toy();
        let mut stump = DecisionTree::new(TreeParams {
            max_depth: 1,
            ..Default::default()
        });
        stump.fit(&ds, &mut Rng::new(0));
        assert!(stump.depth() <= 1);
    }

    #[test]
    fn weighted_fit_biases_toward_heavy_rows() {
        // Two overlapping points with conflicting labels; weight decides.
        let x: Vec<f32> = vec![0.0, 0.0, 1.0];
        let ds = Dataset::new("w", x, 3, 1, vec![0, 1, 1], 2);
        let mut tree = DecisionTree::new(TreeParams { max_depth: 1, ..Default::default() });
        // row1 (class 1 at x=0) massively heavier than row0
        tree.fit_weighted(&ds, &[0.01, 10.0, 1.0], &mut Rng::new(0));
        assert_eq!(tree.predict(&ds)[0], 1, "heavy class wins the leaf");
    }

    #[test]
    fn feature_subsampling_still_learns() {
        let ds = clean_toy();
        let mut tree = DecisionTree::new(TreeParams {
            max_features: Some(2),
            ..Default::default()
        });
        tree.fit(&ds, &mut Rng::new(1));
        let acc = accuracy(&ds.y, &tree.predict(&ds));
        assert!(acc > 0.7, "subsampled accuracy {acc}");
    }

    #[test]
    fn single_class_dataset_yields_leaf() {
        let ds = Dataset::new("one", vec![1.0, 2.0, 3.0], 3, 1, vec![0, 0, 0], 1);
        let mut tree = DecisionTree::new(TreeParams::default());
        tree.fit(&ds, &mut Rng::new(0));
        assert_eq!(tree.depth(), 0);
        assert_eq!(tree.predict(&ds), vec![0, 0, 0]);
    }

    #[test]
    fn constant_features_yield_leaf() {
        let ds = Dataset::new("const", vec![5.0; 4], 4, 1, vec![0, 1, 0, 1], 2);
        let mut tree = DecisionTree::new(TreeParams::default());
        tree.fit(&ds, &mut Rng::new(0));
        assert_eq!(tree.depth(), 0, "no valid split on constant feature");
    }
}
