//! AdaBoost (multi-class SAMME) over depth-1 decision stumps — the paper's
//! `AdaBoost` grid entry.
//!
//! SAMME (Zhu et al. 2009): at round m, fit a weak learner on weighted data,
//! compute weighted error `err_m`, set
//! `alpha_m = ln((1-err_m)/err_m) + ln(K-1)`, upweight misclassified rows,
//! renormalize. Prediction sums `alpha_m` per predicted class.

use crate::ml::data::Dataset;
use crate::ml::tree::{Classifier, DecisionTree, TreeParams};
use crate::util::rng::Rng;

/// Boosting hyperparameters.
#[derive(Debug, Clone)]
pub struct AdaBoostParams {
    /// Boosting rounds (weak learners trained).
    pub n_rounds: usize,
    /// Depth of each weak learner (1 = stumps, the classic choice).
    pub stump_depth: usize,
}

impl Default for AdaBoostParams {
    fn default() -> Self {
        AdaBoostParams { n_rounds: 40, stump_depth: 1 }
    }
}

/// A fitted SAMME ensemble.
#[derive(Debug, Clone)]
pub struct AdaBoost {
    params: AdaBoostParams,
    learners: Vec<(f64, DecisionTree)>,
    n_classes: usize,
}

impl AdaBoost {
    /// An unfitted ensemble with the given hyperparameters.
    pub fn new(params: AdaBoostParams) -> Self {
        AdaBoost { params, learners: Vec::new(), n_classes: 0 }
    }

    /// Weak learners actually kept (early-stop may trim rounds).
    pub fn n_rounds_fitted(&self) -> usize {
        self.learners.len()
    }
}

impl Classifier for AdaBoost {
    fn fit(&mut self, train: &Dataset, rng: &mut Rng) {
        self.n_classes = train.n_classes;
        self.learners.clear();
        let n = train.n_rows;
        let k = train.n_classes as f64;
        let mut weights = vec![1.0 / n as f64; n];

        for round in 0..self.params.n_rounds {
            let mut stump = DecisionTree::new(TreeParams {
                max_depth: self.params.stump_depth,
                min_samples_split: 2,
                max_features: None,
            });
            let mut round_rng = rng.fork(round as u64);
            stump.fit_weighted(train, &weights, &mut round_rng);
            let pred = stump.predict(train);

            let err: f64 = weights
                .iter()
                .zip(pred.iter().zip(&train.y))
                .filter(|(_, (p, t))| p != t)
                .map(|(w, _)| w)
                .sum();

            if err >= 1.0 - 1.0 / k {
                // Worse than chance: stop (SAMME requirement err < 1 - 1/K).
                break;
            }
            if err <= 1e-12 {
                // Perfect learner: give it a large finite vote and stop.
                self.learners.push((10.0 + (k - 1.0).ln(), stump));
                break;
            }
            let alpha = ((1.0 - err) / err).ln() + (k - 1.0).ln();
            if alpha <= 0.0 {
                break;
            }
            // Reweight: misclassified rows scale by exp(alpha).
            for (w, (p, t)) in weights.iter_mut().zip(pred.iter().zip(&train.y)) {
                if p != t {
                    *w *= alpha.exp();
                }
            }
            let total: f64 = weights.iter().sum();
            for w in weights.iter_mut() {
                *w /= total;
            }
            self.learners.push((alpha, stump));
        }

        if self.learners.is_empty() {
            // Degenerate data: keep one unweighted stump so predict works.
            let mut stump = DecisionTree::new(TreeParams {
                max_depth: self.params.stump_depth,
                ..Default::default()
            });
            stump.fit(train, rng);
            self.learners.push((1.0, stump));
        }
    }

    fn predict(&self, ds: &Dataset) -> Vec<usize> {
        assert!(!self.learners.is_empty(), "predict before fit");
        let mut scores = vec![vec![0f64; self.n_classes]; ds.n_rows];
        for (alpha, learner) in &self.learners {
            for (r, p) in learner.predict(ds).into_iter().enumerate() {
                scores[r][p] += alpha;
            }
        }
        scores
            .into_iter()
            .map(|s| {
                s.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::dataset::toy;
    use crate::ml::impute::{DummyImputer, Transformer};
    use crate::ml::metrics::accuracy;
    use crate::ml::split::train_test_indices;

    fn clean_toy() -> Dataset {
        let mut ds = toy(0);
        DummyImputer.transform(&mut ds);
        ds
    }

    #[test]
    fn boosting_beats_single_stump() {
        let ds = clean_toy();
        let mut rng = Rng::new(11);
        let (tr, te) = train_test_indices(&ds, 0.3, &mut rng);
        let train = ds.subset(&tr);
        let test = ds.subset(&te);

        let mut stump = DecisionTree::new(TreeParams { max_depth: 1, ..Default::default() });
        stump.fit(&train, &mut Rng::new(1));
        let stump_acc = accuracy(&test.y, &stump.predict(&test));

        let mut ada = AdaBoost::new(AdaBoostParams::default());
        ada.fit(&train, &mut Rng::new(1));
        let ada_acc = accuracy(&test.y, &ada.predict(&test));

        assert!(
            ada_acc >= stump_acc,
            "boosting {ada_acc} should be >= stump {stump_acc}"
        );
        assert!(ada_acc > 0.6, "boosted accuracy {ada_acc}");
    }

    #[test]
    fn multi_round_ensemble_is_built() {
        let ds = clean_toy();
        let mut ada = AdaBoost::new(AdaBoostParams { n_rounds: 15, stump_depth: 1 });
        ada.fit(&ds, &mut Rng::new(2));
        assert!(ada.n_rounds_fitted() >= 2, "rounds {}", ada.n_rounds_fitted());
    }

    #[test]
    fn perfectly_separable_stops_early_but_predicts() {
        let x: Vec<f32> = vec![-2.0, -1.0, 1.0, 2.0];
        let ds = Dataset::new("sep", x, 4, 1, vec![0, 0, 1, 1], 2);
        let mut ada = AdaBoost::new(AdaBoostParams::default());
        ada.fit(&ds, &mut Rng::new(0));
        assert_eq!(ada.predict(&ds), vec![0, 0, 1, 1]);
        assert!(ada.n_rounds_fitted() <= 2);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = clean_toy();
        let run = |seed| {
            let mut ada = AdaBoost::new(AdaBoostParams { n_rounds: 10, stump_depth: 1 });
            ada.fit(&ds, &mut Rng::new(seed));
            ada.predict(&ds)
        };
        assert_eq!(run(4), run(4));
    }

    #[test]
    fn single_class_degenerate_data() {
        let ds = Dataset::new("one", vec![1.0, 2.0, 3.0], 3, 1, vec![0, 0, 0], 1);
        let mut ada = AdaBoost::new(AdaBoostParams::default());
        ada.fit(&ds, &mut Rng::new(0));
        assert_eq!(ada.predict(&ds), vec![0, 0, 0]);
    }
}
