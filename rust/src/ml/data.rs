//! Tabular dataset representation.
//!
//! Row-major `f32` features + integer labels. Missing values are `NaN`
//! (injected by the synthetic generators so that imputation is a real,
//! behaviour-changing pipeline stage — the paper's grid varies the imputer).

/// A dense row-major feature matrix with labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Dataset name (labels reports and task params).
    pub name: String,
    /// Row-major features, `n_rows * n_cols`.
    pub x: Vec<f32>,
    /// Number of rows.
    pub n_rows: usize,
    /// Number of feature columns.
    pub n_cols: usize,
    /// Class labels in `0..n_classes`.
    pub y: Vec<usize>,
    /// Number of distinct classes.
    pub n_classes: usize,
}

impl Dataset {
    /// Assembles a dataset, validating buffer sizes.
    pub fn new(
        name: impl Into<String>,
        x: Vec<f32>,
        n_rows: usize,
        n_cols: usize,
        y: Vec<usize>,
        n_classes: usize,
    ) -> Dataset {
        assert_eq!(x.len(), n_rows * n_cols, "feature buffer size");
        assert_eq!(y.len(), n_rows, "label count");
        debug_assert!(y.iter().all(|&c| c < n_classes), "label out of range");
        Dataset { name: name.into(), x, n_rows, n_cols, y, n_classes }
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.n_cols..(i + 1) * self.n_cols]
    }

    /// Mutable row access.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.x[i * self.n_cols..(i + 1) * self.n_cols]
    }

    /// A new dataset containing the given rows (in the given order).
    pub fn subset(&self, rows: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(rows.len() * self.n_cols);
        let mut y = Vec::with_capacity(rows.len());
        for &r in rows {
            x.extend_from_slice(self.row(r));
            y.push(self.y[r]);
        }
        Dataset {
            name: self.name.clone(),
            x,
            n_rows: rows.len(),
            n_cols: self.n_cols,
            y,
            n_classes: self.n_classes,
        }
    }

    /// Count of NaN cells (missing values).
    pub fn missing_count(&self) -> usize {
        self.x.iter().filter(|v| v.is_nan()).count()
    }

    /// Per-class row counts.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &c in &self.y {
            counts[c] += 1;
        }
        counts
    }

    /// Column-wise means ignoring NaN (0.0 when a column is all-NaN).
    pub fn column_means(&self) -> Vec<f32> {
        let mut sums = vec![0f64; self.n_cols];
        let mut counts = vec![0usize; self.n_cols];
        for r in 0..self.n_rows {
            for (c, &v) in self.row(r).iter().enumerate() {
                if !v.is_nan() {
                    sums[c] += v as f64;
                    counts[c] += 1;
                }
            }
        }
        sums.iter()
            .zip(&counts)
            .map(|(&s, &n)| if n == 0 { 0.0 } else { (s / n as f64) as f32 })
            .collect()
    }

    /// Column-wise (min, max) ignoring NaN; (0, 1) for all-NaN columns.
    pub fn column_min_max(&self) -> Vec<(f32, f32)> {
        let mut mm = vec![(f32::INFINITY, f32::NEG_INFINITY); self.n_cols];
        for r in 0..self.n_rows {
            for (c, &v) in self.row(r).iter().enumerate() {
                if !v.is_nan() {
                    mm[c].0 = mm[c].0.min(v);
                    mm[c].1 = mm[c].1.max(v);
                }
            }
        }
        mm.into_iter()
            .map(|(lo, hi)| if lo > hi { (0.0, 1.0) } else { (lo, hi) })
            .collect()
    }

    /// Column-wise (mean, std) ignoring NaN; std floors at 1e-6.
    pub fn column_mean_std(&self) -> Vec<(f32, f32)> {
        let means = self.column_means();
        let mut sq = vec![0f64; self.n_cols];
        let mut counts = vec![0usize; self.n_cols];
        for r in 0..self.n_rows {
            for (c, &v) in self.row(r).iter().enumerate() {
                if !v.is_nan() {
                    let d = v as f64 - means[c] as f64;
                    sq[c] += d * d;
                    counts[c] += 1;
                }
            }
        }
        means
            .iter()
            .zip(sq.iter().zip(&counts))
            .map(|(&m, (&s, &n))| {
                let std = if n == 0 { 1.0 } else { (s / n as f64).sqrt() as f32 };
                (m, std.max(1e-6))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new(
            "tiny",
            vec![
                1.0, 2.0, //
                3.0, 4.0, //
                5.0, f32::NAN,
            ],
            3,
            2,
            vec![0, 1, 0],
            2,
        )
    }

    #[test]
    fn row_access() {
        let d = tiny();
        assert_eq!(d.row(0), &[1.0, 2.0]);
        assert_eq!(d.row(2)[0], 5.0);
        assert!(d.row(2)[1].is_nan());
    }

    #[test]
    fn subset_selects_and_reorders() {
        let d = tiny();
        let s = d.subset(&[2, 0]);
        assert_eq!(s.n_rows, 2);
        assert_eq!(s.row(0)[0], 5.0);
        assert_eq!(s.row(1), &[1.0, 2.0]);
        assert_eq!(s.y, vec![0, 0]);
    }

    #[test]
    fn missing_and_class_counts() {
        let d = tiny();
        assert_eq!(d.missing_count(), 1);
        assert_eq!(d.class_counts(), vec![2, 1]);
    }

    #[test]
    fn column_stats_ignore_nan() {
        let d = tiny();
        let means = d.column_means();
        assert!((means[0] - 3.0).abs() < 1e-6);
        assert!((means[1] - 3.0).abs() < 1e-6); // (2+4)/2
        let mm = d.column_min_max();
        assert_eq!(mm[0], (1.0, 5.0));
        assert_eq!(mm[1], (2.0, 4.0));
        let ms = d.column_mean_std();
        assert!(ms[1].1 > 0.0);
    }

    #[test]
    #[should_panic(expected = "feature buffer size")]
    fn size_mismatch_panics() {
        Dataset::new("bad", vec![1.0], 1, 2, vec![0], 1);
    }
}
