//! Missing-value imputation (the §3 grid's `feature_engineering` stage).
//!
//! - [`DummyImputer`] — the paper's baseline: replaces NaN with 0.0 (models
//!   cannot consume NaN, so "do nothing" still needs a placeholder);
//! - [`SimpleImputer`] — sklearn's default strategy: column means computed
//!   on the *training* split, applied to both splits (no test leakage).

use crate::ml::data::Dataset;

/// Fit-on-train / transform-anything interface shared with the scalers.
pub trait Transformer: Send + Sync {
    /// Learns statistics from a training set.
    fn fit(&mut self, train: &Dataset);
    /// Applies the learned transformation in place.
    fn transform(&self, ds: &mut Dataset);

    /// Fits on `ds` and immediately transforms it.
    fn fit_transform(&mut self, ds: &mut Dataset) {
        self.fit(ds);
        self.transform(ds);
    }
}

/// Replaces NaN with 0.0; learns nothing.
#[derive(Debug, Default, Clone)]
pub struct DummyImputer;

impl Transformer for DummyImputer {
    fn fit(&mut self, _train: &Dataset) {}

    fn transform(&self, ds: &mut Dataset) {
        for v in ds.x.iter_mut() {
            if v.is_nan() {
                *v = 0.0;
            }
        }
    }
}

/// Mean imputation with train-split statistics.
#[derive(Debug, Default, Clone)]
pub struct SimpleImputer {
    means: Vec<f32>,
}

impl Transformer for SimpleImputer {
    fn fit(&mut self, train: &Dataset) {
        self.means = train.column_means();
    }

    fn transform(&self, ds: &mut Dataset) {
        assert_eq!(
            self.means.len(),
            ds.n_cols,
            "SimpleImputer: fit/transform column mismatch"
        );
        for r in 0..ds.n_rows {
            let row = ds.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                if v.is_nan() {
                    *v = self.means[c];
                }
            }
        }
    }
}

/// Constructs an imputer by its §3 config-matrix name.
pub fn imputer_by_name(name: &str) -> Option<Box<dyn Transformer>> {
    match name {
        "DummyImputer" => Some(Box::new(DummyImputer)),
        "SimpleImputer" => Some(Box::new(SimpleImputer::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn with_nans() -> Dataset {
        Dataset::new(
            "t",
            vec![
                1.0, 10.0, //
                3.0, f32::NAN, //
                f32::NAN, 30.0,
            ],
            3,
            2,
            vec![0, 1, 0],
            2,
        )
    }

    #[test]
    fn dummy_zero_fills() {
        let mut ds = with_nans();
        let mut imp = DummyImputer;
        imp.fit_transform(&mut ds);
        assert_eq!(ds.missing_count(), 0);
        assert_eq!(ds.row(1)[1], 0.0);
        assert_eq!(ds.row(2)[0], 0.0);
        assert_eq!(ds.row(0)[0], 1.0, "non-missing untouched");
    }

    #[test]
    fn simple_mean_fills() {
        let mut ds = with_nans();
        let mut imp = SimpleImputer::default();
        imp.fit_transform(&mut ds);
        assert_eq!(ds.missing_count(), 0);
        assert!((ds.row(2)[0] - 2.0).abs() < 1e-6); // mean(1,3)
        assert!((ds.row(1)[1] - 20.0).abs() < 1e-6); // mean(10,30)
    }

    #[test]
    fn simple_uses_train_stats_on_test() {
        let train = with_nans();
        let mut imp = SimpleImputer::default();
        imp.fit(&train);
        let mut test = Dataset::new("test", vec![f32::NAN, f32::NAN], 1, 2, vec![0], 2);
        imp.transform(&mut test);
        assert!((test.row(0)[0] - 2.0).abs() < 1e-6, "train mean applied");
        assert!((test.row(0)[1] - 20.0).abs() < 1e-6);
    }

    #[test]
    fn by_name_constructs() {
        assert!(imputer_by_name("DummyImputer").is_some());
        assert!(imputer_by_name("SimpleImputer").is_some());
        assert!(imputer_by_name("MagicImputer").is_none());
    }

    #[test]
    #[should_panic(expected = "column mismatch")]
    fn transform_before_fit_panics_on_mismatch() {
        let imp = SimpleImputer::default(); // no fit
        let mut ds = with_nans();
        imp.transform(&mut ds);
    }
}
