//! k-nearest-neighbours classifier (extension model family).
//!
//! Brute-force Euclidean search — exact, deterministic, and fast enough for
//! the grid's dataset sizes (≤ 1797 rows). Distance ties break toward the
//! lower row index; vote ties toward the lower class id.

use crate::ml::data::Dataset;
use crate::ml::tree::Classifier;
use crate::util::rng::Rng;

/// KNN hyperparameters.
#[derive(Debug, Clone)]
pub struct KnnParams {
    /// Neighbors consulted per prediction.
    pub k: usize,
}

impl Default for KnnParams {
    fn default() -> Self {
        KnnParams { k: 5 }
    }
}

/// A fitted (memorizing) KNN model.
#[derive(Debug, Clone)]
pub struct Knn {
    params: KnnParams,
    train_x: Vec<f32>,
    train_y: Vec<usize>,
    n_cols: usize,
    n_classes: usize,
}

impl Knn {
    /// An unfitted model with the given hyperparameters.
    pub fn new(params: KnnParams) -> Knn {
        Knn { params, train_x: Vec::new(), train_y: Vec::new(), n_cols: 0, n_classes: 0 }
    }

    fn dist2(&self, row: &[f32], t: usize) -> f64 {
        let base = t * self.n_cols;
        let mut d = 0f64;
        for (j, &v) in row.iter().enumerate() {
            let diff = (v - self.train_x[base + j]) as f64;
            d += diff * diff;
        }
        d
    }
}

impl Classifier for Knn {
    fn fit(&mut self, train: &Dataset, _rng: &mut Rng) {
        self.train_x = train.x.clone();
        self.train_y = train.y.clone();
        self.n_cols = train.n_cols;
        self.n_classes = train.n_classes;
    }

    fn predict(&self, ds: &Dataset) -> Vec<usize> {
        assert!(!self.train_y.is_empty(), "predict before fit");
        assert_eq!(ds.n_cols, self.n_cols, "feature count mismatch");
        let k = self.params.k.clamp(1, self.train_y.len());
        (0..ds.n_rows)
            .map(|r| {
                let row = ds.row(r);
                // Partial selection of the k smallest distances.
                let mut dists: Vec<(f64, usize)> = (0..self.train_y.len())
                    .map(|t| (self.dist2(row, t), t))
                    .collect();
                dists.select_nth_unstable_by(k - 1, |a, b| {
                    a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
                });
                let mut votes = vec![0usize; self.n_classes];
                for &(_, t) in &dists[..k] {
                    votes[self.train_y[t]] += 1;
                }
                votes
                    .iter()
                    .enumerate()
                    .max_by_key(|&(_, &c)| c)
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::dataset::toy;
    use crate::ml::impute::{DummyImputer, Transformer};
    use crate::ml::metrics::accuracy;
    use crate::ml::split::train_test_indices;

    fn clean_toy() -> Dataset {
        let mut ds = toy(0);
        DummyImputer.transform(&mut ds);
        ds
    }

    #[test]
    fn one_nn_memorizes_training_data() {
        let ds = clean_toy();
        let mut knn = Knn::new(KnnParams { k: 1 });
        knn.fit(&ds, &mut Rng::new(0));
        assert_eq!(accuracy(&ds.y, &knn.predict(&ds)), 1.0);
    }

    #[test]
    fn knn_generalizes() {
        let ds = clean_toy();
        let mut rng = Rng::new(1);
        let (tr, te) = train_test_indices(&ds, 0.3, &mut rng);
        let train = ds.subset(&tr);
        let test = ds.subset(&te);
        let mut knn = Knn::new(KnnParams { k: 5 });
        knn.fit(&train, &mut rng);
        let acc = accuracy(&test.y, &knn.predict(&test));
        assert!(acc > 0.85, "knn accuracy {acc}");
    }

    #[test]
    fn k_larger_than_train_clamps() {
        let x: Vec<f32> = vec![0.0, 1.0, 2.0];
        let ds = Dataset::new("mini", x, 3, 1, vec![0, 0, 1], 2);
        let mut knn = Knn::new(KnnParams { k: 50 });
        knn.fit(&ds, &mut Rng::new(0));
        // majority class over the whole (clamped) set is 0
        assert_eq!(knn.predict(&ds), vec![0, 0, 0]);
    }

    #[test]
    fn known_geometry() {
        // train: two clusters at 0 and 10 on one axis
        let ds = Dataset::new(
            "geo",
            vec![0.0, 0.5, 10.0, 10.5],
            4,
            1,
            vec![0, 0, 1, 1],
            2,
        );
        let mut knn = Knn::new(KnnParams { k: 3 });
        knn.fit(&ds, &mut Rng::new(0));
        let probe = Dataset::new("p", vec![1.0, 9.0], 2, 1, vec![0, 0], 2);
        assert_eq!(knn.predict(&probe), vec![0, 1]);
    }

    #[test]
    #[should_panic(expected = "predict before fit")]
    fn unfit_panics() {
        Knn::new(KnnParams::default()).predict(&clean_toy());
    }
}
