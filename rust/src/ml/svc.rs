//! Linear support-vector classifier — the paper's `SVC` grid entry.
//!
//! One-vs-rest linear SVMs trained by SGD on the L2-regularized hinge loss
//! (Pegasos-style step size `1/(lambda * t)`). Multi-class prediction takes
//! the argmax of the per-class margins.

use crate::ml::data::Dataset;
use crate::ml::tree::Classifier;
use crate::util::rng::Rng;

/// SVC hyperparameters.
#[derive(Debug, Clone)]
pub struct SvcParams {
    /// Pegasos epochs.
    pub epochs: usize,
    /// L2 regularization strength (Pegasos lambda).
    pub lambda: f64,
}

impl Default for SvcParams {
    fn default() -> Self {
        SvcParams { epochs: 20, lambda: 1e-3 }
    }
}

/// A fitted one-vs-rest linear SVC.
#[derive(Debug, Clone)]
pub struct LinearSvc {
    params: SvcParams,
    /// Per-class (weights, bias).
    models: Vec<(Vec<f64>, f64)>,
    n_classes: usize,
}

impl LinearSvc {
    /// An unfitted model with the given hyperparameters.
    pub fn new(params: SvcParams) -> Self {
        LinearSvc { params, models: Vec::new(), n_classes: 0 }
    }

    /// Margin of class `c` on a row.
    fn margin(&self, c: usize, row: &[f32]) -> f64 {
        let (w, b) = &self.models[c];
        let dot: f64 = w.iter().zip(row).map(|(wi, &xi)| wi * xi as f64).sum();
        dot + b
    }
}

impl Classifier for LinearSvc {
    fn fit(&mut self, train: &Dataset, rng: &mut Rng) {
        self.n_classes = train.n_classes;
        self.models.clear();
        let n = train.n_rows;
        let d = train.n_cols;
        let lambda = self.params.lambda;

        for class in 0..train.n_classes {
            let mut w = vec![0f64; d];
            let mut b = 0f64;
            let mut t: u64 = 1;
            let mut order: Vec<usize> = (0..n).collect();
            let mut class_rng = rng.fork(class as u64);
            for _ in 0..self.params.epochs {
                class_rng.shuffle(&mut order);
                for &r in &order {
                    let y = if train.y[r] == class { 1.0 } else { -1.0 };
                    let row = train.row(r);
                    let eta = 1.0 / (lambda * t as f64);
                    let margin: f64 =
                        w.iter().zip(row).map(|(wi, &xi)| wi * xi as f64).sum::<f64>() + b;
                    // L2 shrink.
                    let shrink = 1.0 - eta * lambda;
                    for wi in w.iter_mut() {
                        *wi *= shrink;
                    }
                    if y * margin < 1.0 {
                        for (wi, &xi) in w.iter_mut().zip(row) {
                            *wi += eta * y * xi as f64;
                        }
                        b += eta * y * 0.1; // unregularized, damped bias
                    }
                    t += 1;
                }
            }
            self.models.push((w, b));
        }
    }

    fn predict(&self, ds: &Dataset) -> Vec<usize> {
        assert!(!self.models.is_empty(), "predict before fit");
        (0..ds.n_rows)
            .map(|r| {
                let row = ds.row(r);
                (0..self.n_classes)
                    .map(|c| (c, self.margin(c, row)))
                    .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .map(|(c, _)| c)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::dataset::toy;
    use crate::ml::impute::{DummyImputer, Transformer};
    use crate::ml::metrics::accuracy;
    use crate::ml::scale::StandardScaler;
    use crate::ml::split::train_test_indices;

    fn scaled_toy() -> Dataset {
        let mut ds = toy(0);
        DummyImputer.transform(&mut ds);
        let mut scaler = StandardScaler::default();
        scaler.fit_transform(&mut ds);
        ds
    }

    #[test]
    fn separates_linear_data() {
        // Two linearly separable blobs on one feature.
        let x: Vec<f32> = (0..20)
            .map(|i| if i < 10 { -2.0 - (i as f32) * 0.1 } else { 2.0 + (i as f32) * 0.1 })
            .collect();
        let y: Vec<usize> = (0..20).map(|i| usize::from(i >= 10)).collect();
        let ds = Dataset::new("lin", x, 20, 1, y.clone(), 2);
        let mut svc = LinearSvc::new(SvcParams::default());
        svc.fit(&ds, &mut Rng::new(0));
        assert_eq!(svc.predict(&ds), y);
    }

    #[test]
    fn multiclass_toy_generalizes() {
        let ds = scaled_toy();
        let mut rng = Rng::new(21);
        let (tr, te) = train_test_indices(&ds, 0.3, &mut rng);
        let train = ds.subset(&tr);
        let test = ds.subset(&te);
        let mut svc = LinearSvc::new(SvcParams::default());
        svc.fit(&train, &mut rng);
        let acc = accuracy(&test.y, &svc.predict(&test));
        assert!(acc > 0.8, "test accuracy {acc}");
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = scaled_toy();
        let run = |seed| {
            let mut svc = LinearSvc::new(SvcParams { epochs: 5, ..Default::default() });
            svc.fit(&ds, &mut Rng::new(seed));
            svc.predict(&ds)
        };
        assert_eq!(run(3), run(3));
    }

    #[test]
    fn more_epochs_do_not_collapse() {
        let ds = scaled_toy();
        let acc_of = |epochs| {
            let mut svc = LinearSvc::new(SvcParams { epochs, ..Default::default() });
            svc.fit(&ds, &mut Rng::new(5));
            accuracy(&ds.y, &svc.predict(&ds))
        };
        let short = acc_of(2);
        let long = acc_of(30);
        assert!(long >= short - 0.1, "epochs 2: {short}, 30: {long}");
    }

    #[test]
    #[should_panic(expected = "predict before fit")]
    fn predict_unfit_panics() {
        let svc = LinearSvc::new(SvcParams::default());
        svc.predict(&scaled_toy());
    }
}
