//! Multinomial logistic regression via full-batch gradient descent
//! (extension model family).
//!
//! Softmax over per-class linear scores, L2 regularization, fixed-epoch
//! gradient descent with a cosine-decayed step size. Small, deterministic,
//! and a good linear baseline next to the hinge-loss SVC.

use crate::ml::data::Dataset;
use crate::ml::tree::Classifier;
use crate::util::rng::Rng;

/// Hyperparameters.
#[derive(Debug, Clone)]
pub struct LogisticParams {
    /// Gradient-descent epochs.
    pub epochs: usize,
    /// Learning rate.
    pub lr: f64,
    /// L2 regularization strength.
    pub l2: f64,
}

impl Default for LogisticParams {
    fn default() -> Self {
        LogisticParams { epochs: 100, lr: 0.5, l2: 1e-4 }
    }
}

/// A fitted multinomial logistic model.
#[derive(Debug, Clone)]
pub struct LogisticRegression {
    params: LogisticParams,
    /// (n_classes × n_cols) weights + per-class bias.
    w: Vec<f64>,
    b: Vec<f64>,
    n_cols: usize,
    n_classes: usize,
}

impl LogisticRegression {
    /// An unfitted model with the given hyperparameters.
    pub fn new(params: LogisticParams) -> Self {
        LogisticRegression { params, w: Vec::new(), b: Vec::new(), n_cols: 0, n_classes: 0 }
    }

    fn scores(&self, row: &[f32], out: &mut [f64]) {
        for c in 0..self.n_classes {
            let base = c * self.n_cols;
            let mut s = self.b[c];
            for (j, &v) in row.iter().enumerate() {
                s += self.w[base + j] * v as f64;
            }
            out[c] = s;
        }
    }
}

fn softmax_inplace(xs: &mut [f64]) {
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

impl Classifier for LogisticRegression {
    fn fit(&mut self, train: &Dataset, _rng: &mut Rng) {
        self.n_cols = train.n_cols;
        self.n_classes = train.n_classes;
        self.w = vec![0.0; train.n_classes * train.n_cols];
        self.b = vec![0.0; train.n_classes];
        let n = train.n_rows as f64;
        let mut probs = vec![0f64; train.n_classes];
        let mut grad_w = vec![0f64; self.w.len()];
        let mut grad_b = vec![0f64; self.b.len()];

        for epoch in 0..self.params.epochs {
            grad_w.iter_mut().for_each(|g| *g = 0.0);
            grad_b.iter_mut().for_each(|g| *g = 0.0);
            for r in 0..train.n_rows {
                let row = train.row(r);
                self.scores(row, &mut probs);
                softmax_inplace(&mut probs);
                for c in 0..self.n_classes {
                    let err = probs[c] - if train.y[r] == c { 1.0 } else { 0.0 };
                    grad_b[c] += err;
                    let base = c * self.n_cols;
                    for (j, &v) in row.iter().enumerate() {
                        grad_w[base + j] += err * v as f64;
                    }
                }
            }
            // Cosine-decayed step.
            let progress = epoch as f64 / self.params.epochs as f64;
            let lr = self.params.lr * 0.5 * (1.0 + (std::f64::consts::PI * progress).cos());
            for (w, g) in self.w.iter_mut().zip(&grad_w) {
                *w -= lr * (g / n + self.params.l2 * *w);
            }
            for (b, g) in self.b.iter_mut().zip(&grad_b) {
                *b -= lr * g / n;
            }
        }
    }

    fn predict(&self, ds: &Dataset) -> Vec<usize> {
        assert!(!self.w.is_empty(), "predict before fit");
        assert_eq!(ds.n_cols, self.n_cols, "feature count mismatch");
        let mut scores = vec![0f64; self.n_classes];
        (0..ds.n_rows)
            .map(|r| {
                self.scores(ds.row(r), &mut scores);
                scores
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::dataset::toy;
    use crate::ml::impute::{DummyImputer, Transformer};
    use crate::ml::metrics::accuracy;
    use crate::ml::scale::StandardScaler;
    use crate::ml::split::train_test_indices;

    fn prepped_toy() -> Dataset {
        let mut ds = toy(0);
        DummyImputer.transform(&mut ds);
        let mut sc = StandardScaler::default();
        sc.fit_transform(&mut ds);
        ds
    }

    #[test]
    fn softmax_normalizes() {
        let mut xs = vec![1.0, 2.0, 3.0];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
        // large values do not overflow
        let mut big = vec![1000.0, 1001.0];
        softmax_inplace(&mut big);
        assert!(big.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn learns_toy_blobs() {
        let ds = prepped_toy();
        let mut rng = Rng::new(4);
        let (tr, te) = train_test_indices(&ds, 0.3, &mut rng);
        let mut lr = LogisticRegression::new(LogisticParams::default());
        lr.fit(&ds.subset(&tr), &mut rng);
        let test = ds.subset(&te);
        let acc = accuracy(&test.y, &lr.predict(&test));
        assert!(acc > 0.85, "logistic accuracy {acc}");
    }

    #[test]
    fn binary_linear_separation_is_exact() {
        let x: Vec<f32> = (0..20)
            .map(|i| if i < 10 { -1.0 - i as f32 * 0.1 } else { 1.0 + i as f32 * 0.1 })
            .collect();
        let y: Vec<usize> = (0..20).map(|i| usize::from(i >= 10)).collect();
        let ds = Dataset::new("lin", x, 20, 1, y.clone(), 2);
        let mut lr = LogisticRegression::new(LogisticParams::default());
        lr.fit(&ds, &mut Rng::new(0));
        assert_eq!(lr.predict(&ds), y);
    }

    #[test]
    fn l2_shrinks_weights() {
        let ds = prepped_toy();
        let norm = |l2: f64| {
            let mut lr = LogisticRegression::new(LogisticParams { l2, ..Default::default() });
            lr.fit(&ds, &mut Rng::new(0));
            lr.w.iter().map(|w| w * w).sum::<f64>().sqrt()
        };
        assert!(norm(1.0) < norm(1e-6), "heavy l2 must shrink weights");
    }

    #[test]
    #[should_panic(expected = "predict before fit")]
    fn unfit_panics() {
        LogisticRegression::new(LogisticParams::default()).predict(&prepped_toy());
    }
}
