//! PJRT runtime (Layer-3 side of the AOT bridge): loads the HLO-text
//! artifacts produced by `python/compile/aot.py`, compiles them once on the
//! CPU PJRT client, and drives them from the experiment hot path. Python is
//! build-time only.

pub mod artifact;
pub mod mlp;
pub mod pjrt;
pub mod tensor;
