//! PJRT engine: loads HLO-text artifacts and executes them on the CPU
//! client.
//!
//! Wraps the `xla` crate (docs.rs/xla 0.1.6). The interchange format is HLO
//! **text** (`HloModuleProto::from_text_file`): the crate's bundled
//! xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit ids),
//! while the text parser reassigns ids — see /opt/xla-example/README.md.
//!
//! Thread-safety: the `xla` wrapper types hold raw pointers and are not
//! `Send`/`Sync`-annotated, but the underlying PJRT CPU client *is*
//! thread-safe for compilation and execution (it is the same client JAX
//! uses from multi-threaded Python). [`Executable`] therefore wraps the
//! handle in a `Mutex` and asserts `Send + Sync` — all FFI calls are
//! serialized per executable, which is also the fair-benchmark choice
//! (one compute stream), while different executables may run concurrently.

use crate::coordinator::error::MementoError;
use crate::runtime::tensor::Tensor;
use std::path::Path;
use std::sync::Mutex;

/// A compiled, thread-shareable PJRT executable.
pub struct Executable {
    inner: Mutex<xla::PjRtLoadedExecutable>,
    /// Number of outputs in the result tuple (from the manifest).
    pub n_outputs: usize,
    /// The artifact name this executable was compiled from.
    pub name: String,
}

// SAFETY: PJRT CPU executables are internally synchronized for execution;
// we additionally serialize all calls through the Mutex above. The raw
// pointers are never exposed.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable")
            .field("name", &self.name)
            .field("n_outputs", &self.n_outputs)
            .finish_non_exhaustive()
    }
}

/// The PJRT engine: one CPU client, many compiled executables.
pub struct Engine {
    client: Mutex<xla::PjRtClient>,
    /// The PJRT platform name (e.g. `cpu`).
    pub platform: String,
}

// SAFETY: see Executable — the client is used behind a Mutex only.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.platform)
            .finish_non_exhaustive()
    }
}

impl Engine {
    /// Creates a CPU PJRT client.
    pub fn cpu() -> Result<Engine, MementoError> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| MementoError::runtime(format!("PjRtClient::cpu: {e:?}")))?;
        let platform = client.platform_name();
        Ok(Engine { client: Mutex::new(client), platform })
    }

    /// Loads an HLO-text file and compiles it.
    pub fn compile_hlo_text(
        &self,
        path: &Path,
        name: &str,
        n_outputs: usize,
    ) -> Result<Executable, MementoError> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| MementoError::runtime("non-utf8 artifact path"))?,
        )
        .map_err(|e| {
            MementoError::runtime(format!("parse HLO text '{}': {e:?}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .lock()
            .unwrap()
            .compile(&comp)
            .map_err(|e| MementoError::runtime(format!("compile '{name}': {e:?}")))?;
        Ok(Executable { inner: Mutex::new(exe), n_outputs, name: name.to_string() })
    }
}

impl Executable {
    /// Executes with host tensors in, host tensors out.
    ///
    /// The AOT pipeline lowers with `return_tuple=True`, so the single
    /// output buffer is a tuple of `n_outputs` literals.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>, MementoError> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_, _>>()?;
        let result_literal = {
            let exe = self.inner.lock().unwrap();
            let bufs = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| MementoError::runtime(format!("execute '{}': {e:?}", self.name)))?;
            bufs[0][0]
                .to_literal_sync()
                .map_err(|e| MementoError::runtime(format!("fetch result: {e:?}")))?
        };
        let parts = result_literal
            .to_tuple()
            .map_err(|e| MementoError::runtime(format!("untuple result: {e:?}")))?;
        if parts.len() != self.n_outputs {
            return Err(MementoError::runtime(format!(
                "'{}' returned {} outputs, manifest says {}",
                self.name,
                parts.len(),
                self.n_outputs
            )));
        }
        parts.iter().map(Tensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine tests that need artifacts live in rust/tests/runtime_integration.rs
    // (they require `make artifacts` to have run). Here: client creation and
    // error paths that need no artifacts.

    #[test]
    fn engine_creates_cpu_client() {
        let engine = Engine::cpu().expect("cpu client");
        assert_eq!(engine.platform, "cpu");
    }

    #[test]
    fn missing_artifact_is_runtime_error() {
        let engine = Engine::cpu().unwrap();
        let err = engine
            .compile_hlo_text(Path::new("/nonexistent/foo.hlo.txt"), "foo", 1)
            .unwrap_err();
        assert!(matches!(err, MementoError::Runtime(_)), "{err}");
    }
}
