//! Host-side tensors and conversion to/from `xla::Literal`.
//!
//! The runtime's data plane: row-major `f32` buffers with shape metadata,
//! bridged to PJRT literals at the execute boundary.

use crate::coordinator::error::MementoError;

/// A row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes (empty = scalar).
    pub shape: Vec<usize>,
    /// Elements, row-major.
    pub data: Vec<f32>,
}

impl Tensor {
    /// A tensor from a shape and matching row-major data.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data size mismatch"
        );
        Tensor { shape, data }
    }

    /// An all-zeros tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// A rank-0 tensor holding one value.
    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// 2-D accessor (row-major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Converts to an `xla::Literal` with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal, MementoError> {
        let flat = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // Rank-0: reshape to scalar.
            flat.reshape(&[])
                .map_err(|e| MementoError::runtime(format!("scalar reshape: {e:?}")))
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            flat.reshape(&dims)
                .map_err(|e| MementoError::runtime(format!("reshape {:?}: {e:?}", self.shape)))
        }
    }

    /// Reads a literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor, MementoError> {
        let shape = lit
            .array_shape()
            .map_err(|e| MementoError::runtime(format!("literal shape: {e:?}")))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit
            .to_vec::<f32>()
            .map_err(|e| MementoError::runtime(format!("literal to_vec: {e:?}")))?;
        Ok(Tensor::new(dims, data))
    }

    /// Argmax along the last axis of a 2-D tensor.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.shape.len(), 2, "argmax_rows needs rank 2");
        let (n, c) = (self.shape[0], self.shape[1]);
        (0..n)
            .map(|i| {
                let row = &self.data[i * c..(i + 1) * c];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(j, _)| j)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(0, 2), 3.0);
        assert_eq!(t.at2(1, 0), 4.0);
        assert_eq!(t.len(), 6);
        let z = Tensor::zeros(vec![4]);
        assert_eq!(z.data, vec![0.0; 4]);
        let s = Tensor::scalar(7.5);
        assert!(s.shape.is_empty());
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn argmax_rows_basic() {
        let t = Tensor::new(vec![3, 3], vec![0., 1., 0., 5., 2., 3., 0., 0., 9.]);
        assert_eq!(t.argmax_rows(), vec![1, 0, 2]);
    }

    #[test]
    fn literal_roundtrip_matrix_and_scalar() {
        let t = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);

        let s = Tensor::scalar(3.25);
        let lit = s.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back.data, vec![3.25]);
    }
}
