//! The AOT-MLP classifier: Rust driver over the JAX/Pallas artifacts.
//!
//! Implements [`crate::ml::tree::Classifier`], so the PJRT-backed MLP slots
//! into the §3 grid pipeline exactly like the pure-Rust models. Training
//! loops over minibatches calling the `mlp_train_step` executable; inference
//! calls `mlp_predict`. Python is never involved — the artifacts were
//! lowered once by `make artifacts`.
//!
//! Shape adaptation: the artifacts are compiled for fixed
//! (batch, features, classes) = (see `manifest.json`); datasets with fewer
//! features are zero-padded, unused class slots are disabled via the
//! `class_mask` input (masked logits → ~0 probability and ~0 gradient).
//! Batch remainders are padded with all-zero one-hot rows, which contribute
//! exactly zero loss and zero gradient.

use crate::coordinator::error::MementoError;
use crate::ml::data::Dataset;
use crate::ml::tree::Classifier;
use crate::runtime::artifact::ArtifactStore;
use crate::runtime::tensor::Tensor;
use crate::util::rng::Rng;
use std::sync::Arc;

/// MLP training hyperparameters.
#[derive(Debug, Clone)]
pub struct MlpParams {
    /// Training epochs.
    pub epochs: usize,
    /// Learning rate.
    pub lr: f32,
}

impl Default for MlpParams {
    fn default() -> Self {
        MlpParams { epochs: 30, lr: 0.1 }
    }
}

/// A PJRT-backed MLP classifier.
pub struct MlpModel {
    store: Arc<ArtifactStore>,
    params: MlpParams,
    /// (w1, b1, w2, b2) once fitted.
    weights: Option<[Tensor; 4]>,
    class_mask: Vec<f32>,
    n_classes: usize,
    /// Mean loss of the final epoch (observability for sweeps).
    pub final_loss: f32,
}

impl MlpModel {
    /// An unfitted model over the given artifact store.
    pub fn new(store: Arc<ArtifactStore>, params: MlpParams) -> MlpModel {
        MlpModel {
            store,
            params,
            weights: None,
            class_mask: Vec::new(),
            n_classes: 0,
            final_loss: f32::NAN,
        }
    }

    /// He-initialized parameters, deterministic in `rng`.
    fn init_weights(&self, rng: &mut Rng) -> [Tensor; 4] {
        let m = self.store.meta;
        let he = |fan_in: usize| (2.0 / fan_in as f64).sqrt();
        let w1: Vec<f32> = (0..m.features * m.hidden)
            .map(|_| (rng.normal() * he(m.features)) as f32)
            .collect();
        let w2: Vec<f32> = (0..m.hidden * m.classes)
            .map(|_| (rng.normal() * he(m.hidden)) as f32)
            .collect();
        [
            Tensor::new(vec![m.features, m.hidden], w1),
            Tensor::zeros(vec![m.hidden]),
            Tensor::new(vec![m.hidden, m.classes], w2),
            Tensor::zeros(vec![m.classes]),
        ]
    }

    /// Pads a row-slice batch into (x, y_onehot) tensors of the AOT shape.
    fn make_batch(&self, ds: &Dataset, rows: &[usize]) -> (Tensor, Tensor) {
        let m = self.store.meta;
        assert!(ds.n_cols <= m.features, "dataset wider than AOT features");
        let mut x = vec![0f32; m.batch * m.features];
        let mut y = vec![0f32; m.batch * m.classes];
        for (bi, &r) in rows.iter().enumerate().take(m.batch) {
            let src = ds.row(r);
            x[bi * m.features..bi * m.features + ds.n_cols].copy_from_slice(src);
            y[bi * m.classes + ds.y[r]] = 1.0;
        }
        (
            Tensor::new(vec![m.batch, m.features], x),
            Tensor::new(vec![m.batch, m.classes], y),
        )
    }

    fn mask_tensor(&self) -> Tensor {
        Tensor::new(vec![self.store.meta.classes], self.class_mask.clone())
    }

    /// Trains; returns per-epoch mean loss (exposed for the sweep example).
    pub fn fit_with_history(
        &mut self,
        train: &Dataset,
        rng: &mut Rng,
    ) -> Result<Vec<f32>, MementoError> {
        let m = self.store.meta;
        if train.n_classes > m.classes {
            return Err(MementoError::runtime(format!(
                "dataset has {} classes, artifacts support ≤ {}",
                train.n_classes, m.classes
            )));
        }
        self.n_classes = train.n_classes;
        self.class_mask = (0..m.classes)
            .map(|c| if c < train.n_classes { 1.0 } else { 0.0 })
            .collect();
        let step = self.store.executable("mlp_train_step")?;
        let mask = self.mask_tensor();
        let lr = Tensor::scalar(self.params.lr);

        let mut weights = self.init_weights(rng);
        let mut history = Vec::with_capacity(self.params.epochs);
        let mut order: Vec<usize> = (0..train.n_rows).collect();

        for _ in 0..self.params.epochs {
            rng.shuffle(&mut order);
            let mut epoch_loss = 0f64;
            let mut batches = 0usize;
            for chunk in order.chunks(m.batch) {
                let (x, y) = self.make_batch(train, chunk);
                let [w1, b1, w2, b2] = &weights;
                let out = step.run(&[w1, b1, w2, b2, &x, &y, &mask, &lr])?;
                let mut it = out.into_iter();
                let (nw1, nb1, nw2, nb2, loss) = (
                    it.next().unwrap(),
                    it.next().unwrap(),
                    it.next().unwrap(),
                    it.next().unwrap(),
                    it.next().unwrap(),
                );
                weights = [nw1, nb1, nw2, nb2];
                epoch_loss += loss.data[0] as f64;
                batches += 1;
            }
            history.push((epoch_loss / batches.max(1) as f64) as f32);
        }
        self.final_loss = history.last().copied().unwrap_or(f32::NAN);
        self.weights = Some(weights);
        Ok(history)
    }

    /// Predicts labels (errors become panics via the Classifier trait; use
    /// this method directly for a Result).
    pub fn try_predict(&self, ds: &Dataset) -> Result<Vec<usize>, MementoError> {
        let weights = self
            .weights
            .as_ref()
            .ok_or_else(|| MementoError::runtime("predict before fit"))?;
        let m = self.store.meta;
        let exe = self.store.executable("mlp_predict")?;
        let mask = self.mask_tensor();
        let mut preds = Vec::with_capacity(ds.n_rows);
        let rows: Vec<usize> = (0..ds.n_rows).collect();
        for chunk in rows.chunks(m.batch) {
            let (x, _) = self.make_batch(ds, chunk);
            let [w1, b1, w2, b2] = weights;
            let out = exe.run(&[w1, b1, w2, b2, &x, &mask])?;
            let logits = &out[0];
            let batch_preds = logits.argmax_rows();
            preds.extend_from_slice(&batch_preds[..chunk.len()]);
        }
        Ok(preds)
    }
}

impl Classifier for MlpModel {
    fn fit(&mut self, train: &Dataset, rng: &mut Rng) {
        self.fit_with_history(train, rng).expect("mlp fit failed");
    }

    fn predict(&self, ds: &Dataset) -> Vec<usize> {
        self.try_predict(ds).expect("mlp predict failed")
    }
}

// Integration tests (requiring built artifacts) live in
// rust/tests/runtime_integration.rs; unit tests here cover the pure-host
// batch/padding logic via a store-free path.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_default_sane() {
        let p = MlpParams::default();
        assert!(p.epochs > 0);
        assert!(p.lr > 0.0);
    }
}
