//! Artifact store: discovers and validates `artifacts/manifest.json`,
//! compiles executables on first use, and caches them process-wide.
//!
//! The manifest (written by `python/compile/aot.py`) is the contract
//! between the layers: input/output names, shapes, dtypes, and ordering
//! for every AOT-lowered function, plus the model's padded dimensions.

use crate::coordinator::error::MementoError;
use crate::runtime::pjrt::{Engine, Executable};
use crate::util::json::{parse, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

/// Shape/dtype spec of one input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Parameter name in the AOT signature.
    pub name: String,
    /// Expected dimension sizes.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Option<TensorSpec> {
        let name = j.get("name")?.as_str()?.to_string();
        let shape = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Option<Vec<_>>>()?;
        Some(TensorSpec { name, shape })
    }

    /// Total element count of the spec's shape.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Manifest entry for one AOT function.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Function name (manifest key).
    pub name: String,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// Input signatures, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output signatures, in result-tuple order.
    pub outputs: Vec<TensorSpec>,
}

/// The model's padded dimensions (shared AOT shapes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelMeta {
    /// Padded batch size.
    pub batch: usize,
    /// Padded feature count.
    pub features: usize,
    /// Hidden-layer width.
    pub hidden: usize,
    /// Padded class count.
    pub classes: usize,
}

/// Parsed manifest + lazily compiled executables.
pub struct ArtifactStore {
    dir: PathBuf,
    /// The model family's padded dimensions.
    pub meta: ModelMeta,
    specs: BTreeMap<String, ArtifactSpec>,
    engine: Arc<Engine>,
    compiled: Mutex<BTreeMap<String, Arc<Executable>>>,
}

impl std::fmt::Debug for ArtifactStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactStore")
            .field("dir", &self.dir)
            .field("meta", &self.meta)
            .field("artifacts", &self.specs.keys().collect::<Vec<_>>())
            .finish_non_exhaustive()
    }
}

impl ArtifactStore {
    /// Opens the artifact directory and parses its manifest.
    pub fn open(dir: impl Into<PathBuf>) -> Result<ArtifactStore, MementoError> {
        let dir = dir.into();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            MementoError::runtime(format!(
                "cannot read '{}' (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let doc = parse(&text)
            .map_err(|e| MementoError::runtime(format!("manifest parse: {e}")))?;

        let meta_json = doc
            .get("meta")
            .ok_or_else(|| MementoError::runtime("manifest missing 'meta'"))?;
        let get_dim = |k: &str| -> Result<usize, MementoError> {
            meta_json
                .get(k)
                .and_then(|j| j.as_usize())
                .ok_or_else(|| MementoError::runtime(format!("manifest meta missing '{k}'")))
        };
        let meta = ModelMeta {
            batch: get_dim("batch")?,
            features: get_dim("features")?,
            hidden: get_dim("hidden")?,
            classes: get_dim("classes")?,
        };

        let mut specs = BTreeMap::new();
        let artifacts = doc
            .get("artifacts")
            .and_then(|j| j.as_obj())
            .ok_or_else(|| MementoError::runtime("manifest missing 'artifacts'"))?;
        for (name, entry) in artifacts {
            let file = entry
                .get("file")
                .and_then(|j| j.as_str())
                .ok_or_else(|| MementoError::runtime(format!("artifact '{name}' missing file")))?
                .to_string();
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>, MementoError> {
                entry
                    .get(key)
                    .and_then(|j| j.as_arr())
                    .ok_or_else(|| {
                        MementoError::runtime(format!("artifact '{name}' missing {key}"))
                    })?
                    .iter()
                    .map(|s| {
                        TensorSpec::from_json(s).ok_or_else(|| {
                            MementoError::runtime(format!("artifact '{name}' bad {key} spec"))
                        })
                    })
                    .collect()
            };
            let spec = ArtifactSpec {
                name: name.clone(),
                file,
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
            };
            // Fail early if the HLO file is gone.
            let hlo = dir.join(&spec.file);
            if !hlo.exists() {
                return Err(MementoError::runtime(format!(
                    "artifact file '{}' missing",
                    hlo.display()
                )));
            }
            specs.insert(name.clone(), spec);
        }

        Ok(ArtifactStore {
            dir,
            meta,
            specs,
            engine: shared_engine()?,
            compiled: Mutex::new(BTreeMap::new()),
        })
    }

    /// Default repo-relative artifact directory.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// The manifest's function names.
    pub fn names(&self) -> Vec<&str> {
        self.specs.keys().map(|s| s.as_str()).collect()
    }

    /// The manifest entry for `name`, if any.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// The artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Returns (compiling on first use) the executable for `name`.
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>, MementoError> {
        if let Some(exe) = self.compiled.lock().unwrap().get(name) {
            return Ok(Arc::clone(exe));
        }
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| MementoError::runtime(format!("unknown artifact '{name}'")))?;
        // Compile outside the cache lock (compilation takes ~100ms+).
        let exe = Arc::new(self.engine.compile_hlo_text(
            &self.dir.join(&spec.file),
            name,
            spec.outputs.len(),
        )?);
        let mut cache = self.compiled.lock().unwrap();
        Ok(Arc::clone(cache.entry(name.to_string()).or_insert(exe)))
    }

    /// Number of executables compiled so far (for tests/benches).
    pub fn compiled_count(&self) -> usize {
        self.compiled.lock().unwrap().len()
    }
}

/// Process-wide PJRT engine (one CPU client per process — creating clients
/// is expensive and they are internally multi-threaded).
fn shared_engine() -> Result<Arc<Engine>, MementoError> {
    static ENGINE: OnceLock<Result<Arc<Engine>, String>> = OnceLock::new();
    ENGINE
        .get_or_init(|| Engine::cpu().map(Arc::new).map_err(|e| e.to_string()))
        .clone()
        .map_err(MementoError::Runtime)
}

/// Process-wide artifact store for the default directory (examples and the
/// grid experiment share compiled executables through this).
pub fn shared_store() -> Result<Arc<ArtifactStore>, MementoError> {
    static STORE: OnceLock<Result<Arc<ArtifactStore>, String>> = OnceLock::new();
    STORE
        .get_or_init(|| {
            ArtifactStore::open(ArtifactStore::default_dir())
                .map(Arc::new)
                .map_err(|e| e.to_string())
        })
        .clone()
        .map_err(MementoError::Runtime)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;

    #[test]
    fn tensor_spec_parsing() {
        let j = parse(r#"{"name": "w1", "shape": [64, 32], "dtype": "f32"}"#).unwrap();
        let s = TensorSpec::from_json(&j).unwrap();
        assert_eq!(s.name, "w1");
        assert_eq!(s.shape, vec![64, 32]);
        assert_eq!(s.numel(), 2048);
        // scalar
        let j = parse(r#"{"name": "lr", "shape": []}"#).unwrap();
        assert_eq!(TensorSpec::from_json(&j).unwrap().numel(), 1);
        // malformed
        let j = parse(r#"{"shape": [1]}"#).unwrap();
        assert!(TensorSpec::from_json(&j).is_none());
    }

    #[test]
    fn open_missing_dir_mentions_make_artifacts() {
        let err = ArtifactStore::open("/nonexistent/artifacts").unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn open_rejects_manifest_without_files() {
        let td = TempDir::new("artifacts").unwrap();
        let manifest = r#"{
            "meta": {"batch": 1, "features": 1, "hidden": 1, "classes": 1},
            "artifacts": {"ghost": {"file": "ghost.hlo.txt", "inputs": [], "outputs": []}}
        }"#;
        crate::util::fs::atomic_write(&td.join("manifest.json"), manifest.as_bytes()).unwrap();
        let err = ArtifactStore::open(td.path()).unwrap_err();
        assert!(err.to_string().contains("missing"), "{err}");
    }

    #[test]
    fn open_rejects_bad_meta() {
        let td = TempDir::new("artifacts2").unwrap();
        let manifest = r#"{"meta": {"batch": 1}, "artifacts": {}}"#;
        crate::util::fs::atomic_write(&td.join("manifest.json"), manifest.as_bytes()).unwrap();
        let err = ArtifactStore::open(td.path()).unwrap_err();
        assert!(err.to_string().contains("features"), "{err}");
    }
}
