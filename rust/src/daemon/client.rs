//! Client side of the daemon protocol: submit a grid, attach to a run,
//! read the status document, or request a drain — all over one framed
//! connection per operation.
//!
//! The daemon answers every handshake deterministically: a successful
//! `Submit`/`Attach` gets `Accepted{run_id}` before any events flow, and
//! every refusal is a single `Reject{reason}` frame — so client errors
//! are typed strings, never hangs.

use crate::config::matrix::ConfigMatrix;
use crate::coordinator::error::MementoError;
use crate::ipc::proto::{read_frame, write_frame, Msg, PROTOCOL_VERSION};
use crate::ipc::transport::{Endpoint, WireStream};
use crate::util::json::Json;

/// Per-submission options (the knobs `memento submit` exposes).
#[derive(Clone)]
pub struct SubmitOptions {
    /// Tenant to account the run under (quota + store label prefix).
    pub tenant: String,
    /// Experiment name to resolve against the daemon's registry.
    pub exp: Option<String>,
    /// Experiment version override (daemon default when `None`).
    pub version: Option<String>,
    /// Base seed for deterministic per-task seeding.
    pub seed: u64,
    /// Optional human-chosen run label (becomes the run id's suffix;
    /// duplicates are rejected).
    pub label: Option<String>,
}

impl Default for SubmitOptions {
    fn default() -> SubmitOptions {
        SubmitOptions {
            tenant: "default".to_string(),
            exp: None,
            version: None,
            seed: 0,
            label: None,
        }
    }
}

/// A connection-factory handle on a daemon endpoint. Each operation
/// opens its own connection, so one client value can be used for many
/// submissions.
pub struct DaemonClient {
    endpoint: Endpoint,
    token: Option<String>,
}

impl DaemonClient {
    /// A client for the daemon at `endpoint`, presenting `token` on
    /// every handshake.
    pub fn new(endpoint: Endpoint, token: Option<String>) -> DaemonClient {
        DaemonClient { endpoint, token }
    }

    fn connect(&self) -> Result<Box<dyn WireStream>, MementoError> {
        self.endpoint
            .connect()
            .map_err(|e| MementoError::ipc(format!("connect to daemon {}: {e}", self.endpoint)))
    }

    /// Submits a grid and returns the accepted run's event stream, or
    /// the daemon's typed rejection reason.
    pub fn submit(
        &self,
        matrix: &ConfigMatrix,
        opts: &SubmitOptions,
    ) -> Result<RunHandle, MementoError> {
        let mut stream = self.connect()?;
        let frame = Msg::Submit {
            protocol: PROTOCOL_VERSION,
            token: self.token.clone(),
            tenant: opts.tenant.clone(),
            matrix: matrix.to_json(),
            exp: opts.exp.clone(),
            version: opts.version.clone(),
            seed: opts.seed,
            label: opts.label.clone(),
        };
        write_frame(&mut stream, &frame)
            .map_err(|e| MementoError::ipc(format!("send submission: {e}")))?;
        expect_accepted(stream, "submission")
    }

    /// Re-attaches to an accepted run; the handle replays the terminal
    /// events the client missed, then streams live ones.
    pub fn attach(&self, run_id: &str) -> Result<RunHandle, MementoError> {
        let mut stream = self.connect()?;
        let frame = Msg::Attach {
            protocol: PROTOCOL_VERSION,
            token: self.token.clone(),
            run_id: run_id.to_string(),
        };
        write_frame(&mut stream, &frame)
            .map_err(|e| MementoError::ipc(format!("send attach: {e}")))?;
        expect_accepted(stream, "attach")
    }

    /// Fetches the daemon's status document.
    pub fn status(&self) -> Result<Json, MementoError> {
        let mut handle = self.attach("")?;
        handle
            .next_event()?
            .ok_or_else(|| MementoError::ipc("daemon closed status channel without a document"))
    }

    /// Asks the daemon to drain: no new launches, in-flight runs
    /// cancelled, queued submissions kept pending for the next daemon
    /// life. Returns once the daemon has acknowledged by closing the
    /// status channel.
    pub fn request_shutdown(&self) -> Result<(), MementoError> {
        let mut handle = self.attach("")?;
        write_frame(&mut handle.stream, &Msg::Shutdown)
            .map_err(|e| MementoError::ipc(format!("send shutdown: {e}")))?;
        while handle.next_event()?.is_some() {}
        Ok(())
    }
}

/// Reads the handshake answer: `Accepted` yields a [`RunHandle`],
/// `Reject` surfaces the daemon's reason, anything else is a protocol
/// error.
fn expect_accepted(
    mut stream: Box<dyn WireStream>,
    what: &str,
) -> Result<RunHandle, MementoError> {
    match read_frame(&mut stream) {
        Ok(Some(Msg::Accepted { run_id })) => Ok(RunHandle { stream, run_id }),
        Ok(Some(Msg::Reject { reason })) => {
            Err(MementoError::ipc(format!("{what} rejected: {reason}")))
        }
        Ok(Some(_)) => Err(MementoError::ipc(format!("unexpected reply to {what}"))),
        Ok(None) => Err(MementoError::ipc(format!("daemon closed the connection mid-{what}"))),
        Err(e) => Err(MementoError::ipc(format!("read {what} reply: {e}"))),
    }
}

/// An accepted run's event stream. Dropping the handle (or calling
/// [`detach`](RunHandle::detach)) only closes this connection — the run
/// keeps executing on the daemon.
pub struct RunHandle {
    stream: Box<dyn WireStream>,
    run_id: String,
}

impl RunHandle {
    /// The daemon-assigned run id (`tenant/...`), usable with `attach`.
    pub fn run_id(&self) -> &str {
        &self.run_id
    }

    /// The next event document, `Ok(None)` once the stream is complete
    /// (the run finished and everything was delivered), or the daemon's
    /// typed rejection as an error.
    pub fn next_event(&mut self) -> Result<Option<Json>, MementoError> {
        loop {
            match read_frame(&mut self.stream) {
                Ok(Some(Msg::Event { event, .. })) => return Ok(Some(event)),
                Ok(Some(Msg::Reject { reason })) => {
                    return Err(MementoError::ipc(format!("stream rejected: {reason}")))
                }
                Ok(Some(_)) => {}
                Ok(None) => return Ok(None),
                Err(e) => return Err(MementoError::ipc(format!("read event: {e}"))),
            }
        }
    }

    /// Politely detaches: tells the daemon this connection is done and
    /// closes it. The run is unaffected; `attach` later replays what was
    /// missed.
    pub fn detach(mut self) {
        let _ = write_frame(&mut self.stream, &Msg::Detach);
        let _ = self.stream.shutdown_both();
    }
}
