//! Multi-tenant memento daemon (Layer 4): a long-running run-submission
//! service over the framed wire transport.
//!
//! Where a plain [`crate::coordinator::memento::Memento`] run owns its
//! supervisor, store, and worker fleet for the length of one grid, the
//! daemon inverts the lifetimes: **one** process owns one
//! [`crate::store::ResultStore`], one shared
//! [`crate::coordinator::cache::ResultCache`], and one
//! [`crate::ipc::pool::WorkerPool`], and many clients submit grids into
//! it over the same token-authenticated transport workers use. Each
//! accepted submission becomes an ordinary coordinator run — same
//! journal, trace, retry, and checkpoint machinery — scheduled by a
//! bounded FIFO [`queue::AdmissionQueue`] with a per-tenant in-flight
//! quota, deduplicated across tenants by the shared cache plus the
//! cross-run [`crate::coordinator::inflight::InflightGate`].
//!
//! The wire protocol (v6) adds five frames: `Submit` →
//! `Accepted{run_id}` | `Reject{reason}`, then an `Event` stream;
//! `Attach{run_id}` resumes a stream (the empty run id serves the status
//! document and accepts a `Shutdown` drain request); `Detach` ends a
//! connection without touching the run. Client disconnects never kill
//! runs; terminal events are retained (in memory and in each run's
//! `events.jsonl`) so a later attach replays exactly what was missed.
//!
//! Module map: [`service`] — daemon lifecycle, scheduler, event tee;
//! [`queue`] — admission + quota; `session` (crate-private) —
//! per-connection protocol handling; [`client`] — the submit / attach /
//! status / shutdown client the CLI verbs wrap.

pub mod client;
pub mod queue;
pub mod service;
pub(crate) mod session;

pub use client::{DaemonClient, RunHandle, SubmitOptions};
pub use queue::{AdmissionQueue, RunPhase, RunRow};
pub use service::{Daemon, DaemonOptions};
