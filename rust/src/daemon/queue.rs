//! Admission control: the daemon's bounded FIFO queue and per-tenant
//! in-flight quota.
//!
//! A submission that passes authentication and validation lands here.
//! Admission is all-or-nothing: a full queue answers `Reject{reason}`
//! immediately (the client is never left hanging), and an admitted run
//! sits in FIFO order until the scheduler asks for the next *eligible*
//! run — the oldest queued run whose tenant is below its `max_in_flight`
//! quota. A tenant at quota does not block other tenants: the scheduler
//! skips over its queued runs and keeps serving the rest, which is what
//! keeps one greedy tenant from starving the fleet.

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

/// Lifecycle phase of a submitted run, as tracked by the admission queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunPhase {
    /// Admitted, waiting for the scheduler (FIFO order, quota permitting).
    Queued,
    /// Executing on the shared worker pool.
    Running,
    /// Finished with zero failed tasks.
    Done,
    /// Finished with failures, aborted, or failed to launch.
    Failed,
}

impl RunPhase {
    /// Stable lowercase rendering for status documents.
    pub fn as_str(&self) -> &'static str {
        match self {
            RunPhase::Queued => "queued",
            RunPhase::Running => "running",
            RunPhase::Done => "done",
            RunPhase::Failed => "failed",
        }
    }
}

/// One run's row in the daemon's status table.
#[derive(Debug, Clone)]
pub struct RunRow {
    /// The daemon-assigned run id (`tenant/...` store label).
    pub run_id: String,
    /// The tenant the run is accounted under.
    pub tenant: String,
    /// Current lifecycle phase.
    pub phase: RunPhase,
}

#[derive(Default)]
struct QueueState {
    /// Run ids in admission order; only `Queued` rows appear here.
    fifo: VecDeque<String>,
    /// Every admitted run, by id (including finished ones, for status).
    rows: HashMap<String, RunRow>,
    /// Admission order of all runs, for stable status listings.
    order: Vec<String>,
}

/// Bounded FIFO admission queue with a per-tenant in-flight quota. All
/// methods are internally synchronized; the handle is shared between
/// session threads (admit) and the scheduler thread (dispatch).
pub struct AdmissionQueue {
    max_queue: usize,
    max_in_flight: usize,
    state: Mutex<QueueState>,
}

impl AdmissionQueue {
    /// Creates a queue holding at most `max_queue` waiting runs, with at
    /// most `max_in_flight` concurrently running runs per tenant (both
    /// min 1).
    pub fn new(max_queue: usize, max_in_flight: usize) -> AdmissionQueue {
        AdmissionQueue {
            max_queue: max_queue.max(1),
            max_in_flight: max_in_flight.max(1),
            state: Mutex::new(QueueState::default()),
        }
    }

    /// The per-tenant in-flight quota this queue enforces.
    pub fn max_in_flight(&self) -> usize {
        self.max_in_flight
    }

    /// The maximum number of waiting runs before admission rejects.
    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    /// Admits `run_id` for `tenant`, or explains why not (full queue,
    /// duplicate id). The rejection string travels verbatim in the wire
    /// `Reject` frame.
    pub fn admit(&self, run_id: &str, tenant: &str) -> Result<(), String> {
        let mut st = self.state.lock().unwrap();
        if st.rows.contains_key(run_id) {
            return Err(format!("run id {run_id:?} already submitted"));
        }
        if st.fifo.len() >= self.max_queue {
            return Err(format!(
                "admission queue full ({} waiting, max {}); retry later",
                st.fifo.len(),
                self.max_queue
            ));
        }
        st.fifo.push_back(run_id.to_string());
        st.order.push(run_id.to_string());
        st.rows.insert(
            run_id.to_string(),
            RunRow {
                run_id: run_id.to_string(),
                tenant: tenant.to_string(),
                phase: RunPhase::Queued,
            },
        );
        Ok(())
    }

    /// Pops the oldest queued run whose tenant is under quota and marks
    /// it `Running`. Returns `None` when nothing is eligible — either the
    /// queue is empty or every waiting tenant is at `max_in_flight`
    /// (those runs stay queued, in order, and become eligible again as
    /// their tenant's runs finish).
    pub fn next_ready(&self) -> Option<String> {
        let mut st = self.state.lock().unwrap();
        let mut running: HashMap<String, usize> = HashMap::new();
        for row in st.rows.values() {
            if row.phase == RunPhase::Running {
                *running.entry(row.tenant.clone()).or_insert(0) += 1;
            }
        }
        let pos = st.fifo.iter().position(|id| {
            let tenant = &st.rows[id].tenant;
            running.get(tenant).copied().unwrap_or(0) < self.max_in_flight
        })?;
        let id = st.fifo.remove(pos).expect("position just found");
        st.rows.get_mut(&id).expect("row exists").phase = RunPhase::Running;
        Some(id)
    }

    /// Records a run's terminal phase, releasing its tenant's quota slot.
    pub fn finish(&self, run_id: &str, ok: bool) {
        let mut st = self.state.lock().unwrap();
        if let Some(row) = st.rows.get_mut(run_id) {
            row.phase = if ok { RunPhase::Done } else { RunPhase::Failed };
        }
    }

    /// Evicts finished rows beyond the newest `keep` per tenant,
    /// returning the evicted run ids in admission order. Queued and
    /// running rows are never evicted. Without this a long-running
    /// daemon's status table (and the channel map keyed off it) grows by
    /// one row per submission forever; evicted runs stay attachable
    /// through their on-disk `events.jsonl`.
    pub fn evict_finished(&self, keep: usize) -> Vec<String> {
        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        let order = st.order.clone();
        let mut kept: HashMap<String, usize> = HashMap::new();
        let mut evicted = Vec::new();
        // Newest-first, so the most recent `keep` finished runs of each
        // tenant survive.
        for id in order.iter().rev() {
            let Some(row) = st.rows.get(id) else {
                continue;
            };
            if !matches!(row.phase, RunPhase::Done | RunPhase::Failed) {
                continue;
            }
            let n = kept.entry(row.tenant.clone()).or_insert(0);
            if *n < keep {
                *n += 1;
            } else {
                st.rows.remove(id);
                evicted.push(id.clone());
            }
        }
        if !evicted.is_empty() {
            let rows = &st.rows;
            st.order.retain(|id| rows.contains_key(id));
            evicted.reverse();
        }
        evicted
    }

    /// Waiting (queued, not yet running) runs.
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().fifo.len()
    }

    /// Currently running runs (all tenants).
    pub fn running(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.rows.values().filter(|r| r.phase == RunPhase::Running).count()
    }

    /// `(tenant, running-count)` pairs for every tenant with at least one
    /// running run, sorted by tenant for stable status output.
    pub fn tenants_in_flight(&self) -> Vec<(String, usize)> {
        let st = self.state.lock().unwrap();
        let mut counts: HashMap<String, usize> = HashMap::new();
        for row in st.rows.values() {
            if row.phase == RunPhase::Running {
                *counts.entry(row.tenant.clone()).or_insert(0) += 1;
            }
        }
        let mut out: Vec<(String, usize)> = counts.into_iter().collect();
        out.sort();
        out
    }

    /// Every admitted run's row, in admission order.
    pub fn rows(&self) -> Vec<RunRow> {
        let st = self.state.lock().unwrap();
        st.order.iter().filter_map(|id| st.rows.get(id).cloned()).collect()
    }

    /// The current phase of `run_id`, if it was ever admitted.
    pub fn phase(&self, run_id: &str) -> Option<RunPhase> {
        self.state.lock().unwrap().rows.get(run_id).map(|r| r.phase)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_within_quota() {
        let q = AdmissionQueue::new(8, 2);
        q.admit("a/1", "a").unwrap();
        q.admit("b/1", "b").unwrap();
        q.admit("a/2", "a").unwrap();
        assert_eq!(q.next_ready().as_deref(), Some("a/1"));
        assert_eq!(q.next_ready().as_deref(), Some("b/1"));
        assert_eq!(q.next_ready().as_deref(), Some("a/2"));
        assert_eq!(q.next_ready(), None);
    }

    #[test]
    fn tenant_at_quota_queues_without_blocking_others() {
        let q = AdmissionQueue::new(8, 1);
        q.admit("a/1", "a").unwrap();
        q.admit("a/2", "a").unwrap();
        q.admit("b/1", "b").unwrap();
        assert_eq!(q.next_ready().as_deref(), Some("a/1"));
        // a is at quota: a/2 is skipped, b/1 (younger) dispatches.
        assert_eq!(q.next_ready().as_deref(), Some("b/1"));
        assert_eq!(q.next_ready(), None);
        assert_eq!(q.depth(), 1);
        // Finishing a/1 releases the slot; a/2 becomes eligible.
        q.finish("a/1", true);
        assert_eq!(q.next_ready().as_deref(), Some("a/2"));
        assert_eq!(q.phase("a/1"), Some(RunPhase::Done));
    }

    #[test]
    fn full_queue_and_duplicates_reject_with_reasons() {
        let q = AdmissionQueue::new(2, 4);
        q.admit("a/1", "a").unwrap();
        q.admit("a/2", "a").unwrap();
        let err = q.admit("a/3", "a").unwrap_err();
        assert!(err.contains("queue full"), "got: {err}");
        let err = q.admit("a/1", "a").unwrap_err();
        assert!(err.contains("already submitted"), "got: {err}");
        // Dispatching one frees a slot.
        assert_eq!(q.next_ready().as_deref(), Some("a/1"));
        q.admit("a/3", "a").unwrap();
    }

    #[test]
    fn evict_finished_keeps_newest_per_tenant_and_all_live_rows() {
        let q = AdmissionQueue::new(16, 4);
        for i in 0..4 {
            q.admit(&format!("a/{i}"), "a").unwrap();
        }
        q.admit("b/0", "b").unwrap();
        // Finish a/0..a/2 in order; a/3 dispatches but stays running,
        // b/0 stays queued.
        for i in 0..4 {
            assert_eq!(q.next_ready(), Some(format!("a/{i}")));
        }
        q.finish("a/0", true);
        q.finish("a/1", false);
        q.finish("a/2", true);

        // keep=1: only the newest finished run per tenant survives.
        assert_eq!(q.evict_finished(1), vec!["a/0".to_string(), "a/1".to_string()]);
        let ids: Vec<String> = q.rows().into_iter().map(|r| r.run_id).collect();
        assert_eq!(ids, vec!["a/2", "a/3", "b/0"], "running + queued rows never evict");
        // Nothing further to evict at the same retention.
        assert!(q.evict_finished(1).is_empty());
    }

    #[test]
    fn status_rows_track_phases() {
        let q = AdmissionQueue::new(8, 2);
        q.admit("a/1", "a").unwrap();
        q.admit("b/1", "b").unwrap();
        q.next_ready().unwrap();
        q.finish("a/1", false);
        let rows = q.rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].phase, RunPhase::Failed);
        assert_eq!(rows[1].phase, RunPhase::Queued);
        assert_eq!(q.tenants_in_flight(), Vec::<(String, usize)>::new());
    }
}
