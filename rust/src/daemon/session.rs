//! Per-connection protocol handling: authenticate, admit or attach, then
//! stream run events until the client detaches or the daemon stops.
//!
//! Every accepted connection runs [`handle`] on its own short-lived
//! thread. The first frame decides everything: `Submit` admits a run (or
//! answers `Reject{reason}`), `Attach` resumes an accepted run's event
//! stream (or, with the empty run id, answers the daemon status document
//! and then listens for a `Shutdown` drain request). Authentication —
//! token then protocol version — happens before *any* daemon state is
//! revealed, the same rule the worker pool applies to registrations: a
//! bad token learns nothing beyond "rejected".
//!
//! Disconnect semantics are deliberately asymmetric: a client vanishing
//! (EOF, write error, `Detach` frame) only unsubscribes the connection —
//! the run keeps executing and draining into the shared store, and a
//! later `Attach` replays the terminal events it missed from the
//! [`RunChannel`] history (or, after a daemon restart, from the run's
//! `events.jsonl`).

use crate::config::loader;
use crate::daemon::service::{DaemonShared, ParsedSubmission};
use crate::ipc::proto::{read_frame, write_frame, Msg, PROTOCOL_VERSION};
use crate::ipc::transport::WireStream;
use crate::store;
use crate::util::json::Json;
use crate::util::sha256;
use std::io;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How long a fresh connection gets to deliver its first frame.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Read-poll interval while streaming events (bounds both detach latency
/// and daemon-stop latency for an idle attached client).
const POLL: Duration = Duration::from_millis(50);

/// Fan-out hub for one run's events: retained history (terminal events,
/// replayed to late attachers) plus live subscriber channels. The lock
/// makes replay-then-subscribe atomic — an event is either in the history
/// a subscriber copies or delivered live afterwards, never both and never
/// neither.
pub(crate) struct RunChannel {
    inner: Mutex<ChannelInner>,
}

struct ChannelInner {
    history: Vec<Json>,
    subs: Vec<Sender<Json>>,
    done: bool,
}

impl RunChannel {
    /// A fresh hub with no history and no subscribers.
    pub(crate) fn new() -> Arc<RunChannel> {
        Arc::new(RunChannel {
            inner: Mutex::new(ChannelInner { history: Vec::new(), subs: Vec::new(), done: false }),
        })
    }

    /// Delivers `event` to every live subscriber (dead ones are dropped)
    /// and, when `retain` is set, appends it to the replay history.
    pub(crate) fn publish(&self, event: Json, retain: bool) {
        let mut inner = self.inner.lock().unwrap();
        inner.subs.retain(|tx| tx.send(event.clone()).is_ok());
        if retain {
            inner.history.push(event);
        }
    }

    /// Marks the run complete: subscribers observe their channel
    /// disconnecting once drained, and future subscribers get history
    /// only.
    pub(crate) fn finish(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.done = true;
        inner.subs.clear();
    }

    /// A copy of the retained history plus, while the run is still live,
    /// a receiver for everything published after this call.
    pub(crate) fn subscribe(&self) -> (Vec<Json>, Option<Receiver<Json>>) {
        let mut inner = self.inner.lock().unwrap();
        let history = inner.history.clone();
        if inner.done {
            (history, None)
        } else {
            let (tx, rx) = std::sync::mpsc::channel();
            inner.subs.push(tx);
            (history, Some(rx))
        }
    }
}

/// `true` for the error kinds a read deadline produces (the poll loops
/// treat these as "no frame yet", anything else as a dead peer).
fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Best-effort terminal `Reject`; the connection closes right after.
fn reject(stream: &mut Box<dyn WireStream>, reason: String) {
    let _ = write_frame(stream, &Msg::Reject { reason });
}

/// Validity gate for tenant names and run labels. Both become path
/// components under the daemon root (`runs/<tenant>/<label>`) and halves
/// of `tenant/label` run ids, so they share one allowlist: non-empty
/// ASCII alphanumerics plus `-`, `_`, `.` — which structurally excludes
/// path separators, `:`, and dots-only names like `..`.
fn valid_id_component(s: &str) -> bool {
    !s.is_empty()
        && s.bytes().all(|b| b.is_ascii_alphanumeric() || matches!(b, b'-' | b'_' | b'.'))
        && !s.bytes().all(|b| b == b'.')
}

/// Token-then-version gate shared by `Submit` and `Attach`. Returns the
/// rejection reason on failure; nothing about the daemon (registry,
/// queue, runs) has been revealed at that point.
fn authenticate(shared: &DaemonShared, protocol: u64, token: Option<&str>) -> Result<(), String> {
    if let Some(expected) = &shared.options.token {
        match token {
            Some(t) if sha256::constant_time_eq(t.as_bytes(), expected.as_bytes()) => {}
            _ => return Err("authentication failed".to_string()),
        }
    }
    if protocol < PROTOCOL_VERSION {
        return Err(format!(
            "daemon submissions require protocol v{PROTOCOL_VERSION}+ (peer sent \
             v{protocol}); `memento serve` workers are unaffected — only the \
             submit/attach client must upgrade"
        ));
    }
    Ok(())
}

/// Entry point for one accepted client connection (runs on its own
/// thread; never panics the daemon — all I/O errors drop the connection).
pub(crate) fn handle(shared: Arc<DaemonShared>, mut stream: Box<dyn WireStream>) {
    let _ = stream.set_stream_read_timeout(Some(HANDSHAKE_TIMEOUT));
    let first = match read_frame(&mut stream) {
        Ok(Some(msg)) => msg,
        Ok(None) | Err(_) => return,
    };
    match first {
        Msg::Submit { protocol, token, tenant, matrix, exp, version, seed, label } => {
            if let Err(reason) = authenticate(&shared, protocol, token.as_deref()) {
                return reject(&mut stream, reason);
            }
            handle_submit(shared, stream, tenant, matrix, exp, version, seed, label);
        }
        Msg::Attach { protocol, token, run_id } => {
            if let Err(reason) = authenticate(&shared, protocol, token.as_deref()) {
                return reject(&mut stream, reason);
            }
            if run_id.is_empty() {
                handle_status(shared, stream);
            } else {
                handle_attach(shared, stream, run_id);
            }
        }
        _ => reject(
            &mut stream,
            "expected a submit or attach frame (daemon endpoint, not a worker pool)".to_string(),
        ),
    }
}

/// Validates, persists, and admits one submission, then streams its
/// events. Every refusal is a typed `Reject{reason}` answered immediately
/// — a bad submission never occupies a queue slot, and a
/// capability-mismatched one (unknown experiment) fails here rather than
/// hanging as an unservable run.
#[allow(clippy::too_many_arguments)]
fn handle_submit(
    shared: Arc<DaemonShared>,
    mut stream: Box<dyn WireStream>,
    tenant: String,
    matrix: Json,
    exp: Option<String>,
    version: Option<String>,
    seed: u64,
    label: Option<String>,
) {
    if !valid_id_component(&tenant) {
        return reject(
            &mut stream,
            format!("invalid tenant {tenant:?}: use letters, digits, '-', '_', '.'"),
        );
    }
    if let Some(l) = &label {
        if !valid_id_component(l) {
            return reject(
                &mut stream,
                format!("invalid label {l:?}: use letters, digits, '-', '_', '.'"),
            );
        }
    }
    let matrix = match loader::from_json(&matrix) {
        Ok(m) => m,
        Err(e) => return reject(&mut stream, format!("invalid config matrix: {e}")),
    };
    if let Some(name) = &exp {
        if shared.registry.get(name).is_none() {
            let names = shared.registry.names();
            return reject(
                &mut stream,
                format!(
                    "unknown experiment {name:?} (registered: {})",
                    if names.is_empty() { "none".to_string() } else { names.join(", ") }
                ),
            );
        }
    }
    let run_id = shared.new_run_id(&tenant, label.as_deref());
    // Claim the id before writing any state: a duplicate (live in this
    // daemon, or with recorded events from an earlier life) is rejected
    // here, so a re-submission can never overwrite or delete the original
    // run's pending file, event channel, or on-disk records.
    if !shared.reserve_run(&run_id) {
        return reject(&mut stream, format!("run id {run_id:?} already submitted"));
    }
    let submission = ParsedSubmission { tenant: tenant.clone(), matrix, exp, version, seed };
    if let Err(e) = shared.persist_pending(&run_id, &submission) {
        shared.uninstall_run(&run_id);
        return reject(&mut stream, format!("persist submission: {e}"));
    }
    shared.install_submission(&run_id, submission);
    if let Err(reason) = shared.queue.admit(&run_id, &tenant) {
        shared.uninstall_run(&run_id);
        shared.remove_pending(&run_id);
        return reject(&mut stream, reason);
    }
    if write_frame(&mut stream, &Msg::Accepted { run_id: run_id.clone() }).is_err() {
        // Client vanished between submit and accept: the run is admitted
        // and executes anyway; a later attach picks the events up.
        return;
    }
    let channel = shared.channel(&run_id).expect("channel installed above");
    stream_events(&shared, stream, &run_id, &channel);
}

/// Answers the status channel: one `Accepted{""}` + one status `Event`,
/// then listens for a `Shutdown` drain request until the peer leaves.
fn handle_status(shared: Arc<DaemonShared>, mut stream: Box<dyn WireStream>) {
    if write_frame(&mut stream, &Msg::Accepted { run_id: String::new() }).is_err() {
        return;
    }
    let status = shared.status_doc();
    if write_frame(&mut stream, &Msg::Event { run_id: String::new(), event: status }).is_err() {
        return;
    }
    let _ = stream.set_stream_read_timeout(Some(POLL));
    loop {
        if shared.stopping() {
            return;
        }
        match read_frame(&mut stream) {
            Ok(Some(Msg::Shutdown)) => {
                shared.begin_drain();
                return;
            }
            Ok(Some(_)) => {}
            Ok(None) => return,
            Err(e) if is_timeout(&e) => {}
            Err(_) => return,
        }
    }
}

/// Re-attaches a client to an accepted run: replays the retained terminal
/// events, then streams live ones while the run is still executing. Runs
/// finished in an earlier daemon life replay from their `events.jsonl`.
fn handle_attach(shared: Arc<DaemonShared>, mut stream: Box<dyn WireStream>, run_id: String) {
    // Daemon-minted ids are always `tenant/short` with both halves
    // allowlisted; anything else (extra separators, `..`, empty parts)
    // never reaches the filesystem — the replay path below joins these
    // components under the daemon root.
    let (tenant, short) = store::split_tenant(&run_id);
    if !valid_id_component(tenant) || !valid_id_component(short) {
        return reject(&mut stream, format!("unknown run id {run_id:?}"));
    }
    match shared.channel(&run_id) {
        Some(channel) => {
            if write_frame(&mut stream, &Msg::Accepted { run_id: run_id.clone() }).is_err() {
                return;
            }
            stream_events(&shared, stream, &run_id, &channel);
        }
        None => match shared.replay_events_file(&run_id) {
            Some(events) => {
                if write_frame(&mut stream, &Msg::Accepted { run_id: run_id.clone() }).is_err() {
                    return;
                }
                for event in events {
                    if write_frame(&mut stream, &Msg::Event { run_id: run_id.clone(), event })
                        .is_err()
                    {
                        return;
                    }
                }
                let _ = stream.shutdown_both();
            }
            None => reject(&mut stream, format!("unknown run id {run_id:?}")),
        },
    }
}

/// The shared streaming loop: replay history, then interleave live events
/// with a polled read watching for `Detach`/EOF. Returning closes the
/// connection; the run is never affected.
fn stream_events(
    shared: &DaemonShared,
    mut stream: Box<dyn WireStream>,
    run_id: &str,
    channel: &RunChannel,
) {
    let (history, live) = channel.subscribe();
    for event in history {
        if write_frame(&mut stream, &Msg::Event { run_id: run_id.to_string(), event }).is_err() {
            return;
        }
    }
    let Some(live) = live else {
        // Run already complete: the history was the full terminal set.
        let _ = stream.shutdown_both();
        return;
    };
    let _ = stream.set_stream_read_timeout(Some(POLL));
    loop {
        loop {
            match live.try_recv() {
                Ok(event) => {
                    if write_frame(
                        &mut stream,
                        &Msg::Event { run_id: run_id.to_string(), event },
                    )
                    .is_err()
                    {
                        return;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // Run complete and everything delivered.
                    let _ = stream.shutdown_both();
                    return;
                }
            }
        }
        if shared.stopping() {
            return;
        }
        match read_frame(&mut stream) {
            Ok(Some(Msg::Detach)) | Ok(None) => return,
            Ok(Some(_)) => {}
            Err(e) if is_timeout(&e) => {}
            Err(_) => return,
        }
    }
}
