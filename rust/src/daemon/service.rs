//! The daemon core: one long-running coordinator multiplexing many
//! tenants' runs onto one shared worker pool and one shared result
//! store.
//!
//! [`Daemon::start`] binds two listeners — the client endpoint (submit /
//! attach / status, handled by [`crate::daemon::session`]) and the worker
//! endpoint (a plain [`WorkerPool`], so `memento serve` workers connect
//! exactly as they would to a single-run supervisor). A scheduler thread
//! pulls eligible runs off the [`AdmissionQueue`] and launches each as an
//! ordinary [`Memento`] run wired to the shared pool, shared
//! [`ResultCache`], and shared [`InflightGate`]; a per-run drain thread
//! tees its events into the run's [`RunChannel`] (live fan-out +
//! replayable history) and `events.jsonl` on disk.
//!
//! Durability: every accepted submission is persisted under
//! `root/pending/` *before* `Accepted` is written, and the pending file
//! is deleted only when the run completes un-cancelled. A drain
//! (`Shutdown` frame) cancels in-flight runs — finished attempts are
//! already in the store, the rest journal as skipped — and a restarted
//! daemon re-admits every pending file: completed cells restore from the
//! shared cache, so nothing is lost and nothing re-executes.

use crate::config::matrix::ConfigMatrix;
use crate::coordinator::cache::ResultCache;
use crate::coordinator::error::MementoError;
use crate::coordinator::inflight::InflightGate;
use crate::coordinator::memento::Memento;
use crate::coordinator::run::RunEvent;
use crate::coordinator::task::fresh_run_id;
use crate::daemon::queue::AdmissionQueue;
use crate::daemon::session::{self, RunChannel};
use crate::experiments::registry::Registry;
use crate::ipc::pool::{PoolOptions, WorkerPool};
use crate::ipc::transport::{poll_accept, Endpoint, Transport};
use crate::store::{self, ResultStore};
use crate::util::codec::WireFormat;
use crate::util::fs as mfs;
use crate::util::json::{self, Json};
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Scheduler poll interval between dispatch attempts.
const SCHED_POLL: Duration = Duration::from_millis(10);

/// Event-drain poll interval per running run.
const DRAIN_POLL: Duration = Duration::from_millis(5);

/// Finished runs retained in memory per tenant — status rows plus replay
/// channels. Older finished runs evict so a long-running daemon's memory
/// and status document stay bounded; evicted runs remain attachable
/// through their on-disk `events.jsonl`.
const RETAIN_FINISHED_PER_TENANT: usize = 32;

/// Configuration for a [`Daemon`].
pub struct DaemonOptions {
    /// Daemon state root; holds `store/`, `runs/`, and `pending/`.
    pub root: PathBuf,
    /// Shared auth token clients *and* workers must present. Required
    /// when either endpoint is TCP.
    pub token: Option<String>,
    /// Maximum queued (not yet running) submissions before `Submit`
    /// answers `Reject`.
    pub max_queue: usize,
    /// Maximum concurrently running runs per tenant.
    pub max_in_flight: usize,
    /// Remote worker slots each run schedules onto (they all share the
    /// one pool; this caps a single run's lease appetite).
    pub workers_per_run: usize,
    /// Wire format for the shared store, caches, and journals.
    pub wire: WireFormat,
    /// Default experiment version recorded for submissions that don't
    /// pin one.
    pub version: String,
    /// Optional per-task wall-clock budget applied to every run.
    pub task_timeout: Option<Duration>,
}

impl DaemonOptions {
    /// Defaults: queue of 64, 2 runs in flight per tenant, 2 worker
    /// slots per run, JSON wire, version `"v1"`, no task timeout.
    pub fn new(root: impl Into<PathBuf>) -> DaemonOptions {
        DaemonOptions {
            root: root.into(),
            token: None,
            max_queue: 64,
            max_in_flight: 2,
            workers_per_run: 2,
            wire: WireFormat::Json,
            version: "v1".to_string(),
            task_timeout: None,
        }
    }
}

/// A validated submission waiting to launch.
pub(crate) struct ParsedSubmission {
    /// Owning tenant (validated: non-empty, no `/` or `:`).
    pub(crate) tenant: String,
    /// The expanded-later configuration grid.
    pub(crate) matrix: ConfigMatrix,
    /// Experiment selection, already resolved against the registry.
    pub(crate) exp: Option<String>,
    /// Experiment version override.
    pub(crate) version: Option<String>,
    /// Base seed for deterministic per-task seeding.
    pub(crate) seed: u64,
}

/// State shared between the acceptor, session threads, the scheduler,
/// and per-run drain threads.
pub(crate) struct DaemonShared {
    /// Daemon configuration (read-only after start).
    pub(crate) options: DaemonOptions,
    /// Experiment registry runs resolve `--exp` names against.
    pub(crate) registry: Arc<Registry>,
    /// The one shared result store.
    pub(crate) store: Arc<ResultStore>,
    /// The one shared cache over that store (all runs dedup through it).
    pub(crate) cache: Arc<ResultCache>,
    /// Cross-run execute-once gate for concurrently running grids.
    pub(crate) gate: Arc<InflightGate>,
    /// The one shared worker pool.
    pub(crate) pool: Arc<WorkerPool>,
    /// Admission queue + per-tenant quota.
    pub(crate) queue: AdmissionQueue,
    /// Live event hubs by run id. Retained after completion for replay,
    /// bounded by [`RETAIN_FINISHED_PER_TENANT`] — older finished runs
    /// drop their hub and replay from `events.jsonl` instead.
    channels: Mutex<HashMap<String, Arc<RunChannel>>>,
    /// Admitted-but-not-yet-launched submissions by run id.
    submissions: Mutex<HashMap<String, ParsedSubmission>>,
    /// Drain-thread handles, joined at shutdown.
    run_joins: Mutex<Vec<JoinHandle<()>>>,
    /// Hard stop: acceptor, scheduler, and session loops exit.
    pub(crate) stop: AtomicBool,
    /// Soft stop: no new launches; running runs are cancelled.
    draining: AtomicBool,
    /// Start instant, for status uptime.
    started: Instant,
}

impl DaemonShared {
    /// `true` once a hard stop is underway (session loops should exit).
    pub(crate) fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// `true` once a drain has been requested.
    pub(crate) fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Requests a drain: stop launching, cancel in-flight runs. The
    /// daemon's `wait()` returns once running runs have drained.
    pub(crate) fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    /// Mints a store-label run id: `tenant/<label or fresh id>`.
    pub(crate) fn new_run_id(&self, tenant: &str, label: Option<&str>) -> String {
        match label {
            Some(l) => store::tenant_label(tenant, l),
            None => store::tenant_label(tenant, &fresh_run_id()),
        }
    }

    /// Atomically claims `run_id` for a new submission by installing its
    /// event channel — but only if the id is unknown: not live in this
    /// daemon life (no channel) and without recorded events from an
    /// earlier one (no `events.jsonl`). Returns `false`, installing
    /// nothing, for a duplicate — the session layer must reject the
    /// submission without touching the original run's state.
    pub(crate) fn reserve_run(&self, run_id: &str) -> bool {
        let mut channels = self.channels.lock().unwrap();
        if channels.contains_key(run_id) || self.run_dir(run_id).join("events.jsonl").exists() {
            return false;
        }
        channels.insert(run_id.to_string(), RunChannel::new());
        true
    }

    /// Installs the parsed submission for a reserved run id.
    pub(crate) fn install_submission(&self, run_id: &str, sub: ParsedSubmission) {
        self.submissions.lock().unwrap().insert(run_id.to_string(), sub);
    }

    /// Installs the event channel and parsed submission for `run_id`
    /// unconditionally — the restart-rescan path, which re-admits runs
    /// that legitimately already have on-disk state (new submissions go
    /// through [`reserve_run`](Self::reserve_run) instead).
    pub(crate) fn install_run(&self, run_id: &str, sub: ParsedSubmission) {
        self.channels.lock().unwrap().insert(run_id.to_string(), RunChannel::new());
        self.submissions.lock().unwrap().insert(run_id.to_string(), sub);
    }

    /// Reverts [`reserve_run`](Self::reserve_run) /
    /// [`install_run`](Self::install_run) after a failed persist or admit.
    pub(crate) fn uninstall_run(&self, run_id: &str) {
        self.channels.lock().unwrap().remove(run_id);
        self.submissions.lock().unwrap().remove(run_id);
    }

    /// The event hub for `run_id`, if it was ever admitted this life.
    pub(crate) fn channel(&self, run_id: &str) -> Option<Arc<RunChannel>> {
        self.channels.lock().unwrap().get(run_id).cloned()
    }

    fn take_submission(&self, run_id: &str) -> Option<ParsedSubmission> {
        self.submissions.lock().unwrap().remove(run_id)
    }

    /// Bounds a long-running daemon's memory: drops finished runs beyond
    /// the newest [`RETAIN_FINISHED_PER_TENANT`] per tenant from the
    /// queue's status table and from the channel map. Called after every
    /// run settles; queued and running runs are never touched.
    pub(crate) fn retire_finished(&self) {
        let evicted = self.queue.evict_finished(RETAIN_FINISHED_PER_TENANT);
        if !evicted.is_empty() {
            let mut channels = self.channels.lock().unwrap();
            for run_id in &evicted {
                channels.remove(run_id);
            }
        }
    }

    /// `root/runs/<tenant>/<short>` for a `tenant/short` run id.
    fn run_dir(&self, run_id: &str) -> PathBuf {
        let (tenant, short) = store::split_tenant(run_id);
        self.options.root.join("runs").join(tenant).join(short)
    }

    fn pending_path(&self, run_id: &str) -> PathBuf {
        self.options.root.join("pending").join(format!("{}.json", run_id.replace('/', "__")))
    }

    /// Durably records an accepted submission so a restarted daemon can
    /// re-admit it. Written *before* the client sees `Accepted`.
    pub(crate) fn persist_pending(
        &self,
        run_id: &str,
        sub: &ParsedSubmission,
    ) -> std::io::Result<()> {
        let doc = Json::obj(vec![
            ("run_id", Json::str(run_id)),
            ("tenant", Json::str(sub.tenant.clone())),
            ("matrix", sub.matrix.to_json()),
            (
                "exp",
                sub.exp.as_ref().map(|e| Json::str(e.clone())).unwrap_or(Json::Null),
            ),
            (
                "version",
                sub.version.as_ref().map(|v| Json::str(v.clone())).unwrap_or(Json::Null),
            ),
            ("seed", Json::str(sub.seed.to_string())),
        ]);
        mfs::atomic_write(&self.pending_path(run_id), doc.to_string().as_bytes())
    }

    /// Drops the pending record once the run completed un-cancelled.
    pub(crate) fn remove_pending(&self, run_id: &str) {
        let _ = std::fs::remove_file(self.pending_path(run_id));
    }

    /// Replays a finished run's `events.jsonl` from disk — the attach
    /// path for runs completed in an earlier daemon life.
    pub(crate) fn replay_events_file(&self, run_id: &str) -> Option<Vec<Json>> {
        let path = self.run_dir(run_id).join("events.jsonl");
        let text = mfs::read_string(&path).ok()?;
        Some(text.lines().filter_map(|l| json::parse(l).ok()).collect())
    }

    /// The status document served on the empty-run-id attach channel.
    pub(crate) fn status_doc(&self) -> Json {
        let rows: Vec<Json> = self
            .queue
            .rows()
            .into_iter()
            .map(|r| {
                Json::obj(vec![
                    ("run_id", Json::str(r.run_id)),
                    ("tenant", Json::str(r.tenant)),
                    ("phase", Json::str(r.phase.as_str())),
                ])
            })
            .collect();
        let tenants: Vec<Json> = self
            .queue
            .tenants_in_flight()
            .into_iter()
            .map(|(t, n)| {
                Json::obj(vec![("tenant", Json::str(t)), ("in_flight", Json::int(n as i64))])
            })
            .collect();
        let stats = self.store.stats();
        Json::obj(vec![
            (
                "daemon",
                Json::obj(vec![
                    ("uptime_secs", Json::num(self.started.elapsed().as_secs_f64())),
                    ("draining", Json::bool(self.draining())),
                    ("version", Json::str(self.options.version.clone())),
                ]),
            ),
            (
                "queue",
                Json::obj(vec![
                    ("depth", Json::int(self.queue.depth() as i64)),
                    ("max", Json::int(self.queue.max_queue() as i64)),
                    ("max_in_flight", Json::int(self.queue.max_in_flight() as i64)),
                ]),
            ),
            ("runs", Json::arr(rows)),
            ("tenants", Json::arr(tenants)),
            (
                "pool",
                Json::obj(vec![
                    ("registered", Json::int(self.pool.registered_count() as i64)),
                    ("available", Json::int(self.pool.available() as i64)),
                    ("leased", Json::int(self.pool.leased_count() as i64)),
                    ("waiting", Json::int(self.pool.waiting_count() as i64)),
                    ("rejected", Json::int(self.pool.rejected_count() as i64)),
                ]),
            ),
            (
                "store",
                Json::obj(vec![
                    ("segments", Json::int(stats.segments as i64)),
                    ("live_records", Json::int(stats.live_records as i64)),
                    ("dedup_hits", Json::int(stats.dedup_hits as i64)),
                    ("runs", Json::int(stats.runs as i64)),
                ]),
            ),
        ])
    }
}

/// A running daemon: handle for shutdown, joining, and endpoint
/// discovery. Dropping without [`wait`](Daemon::wait) leaves threads
/// running detached — call `shutdown()` + `wait()` for a clean exit.
pub struct Daemon {
    shared: Arc<DaemonShared>,
    endpoint: Endpoint,
    acceptor: Option<JoinHandle<()>>,
    scheduler: Option<JoinHandle<()>>,
    _client_dir: Option<mfs::TempDir>,
}

impl Daemon {
    /// Binds both endpoints, re-admits persisted pending submissions,
    /// and starts the acceptor + scheduler threads.
    ///
    /// `client_transport` serves submit/attach/status; `worker_transport`
    /// serves `memento serve` worker registrations. A TCP transport on
    /// either side requires `options.token`.
    pub fn start(
        registry: Registry,
        options: DaemonOptions,
        client_transport: &Transport,
        worker_transport: &Transport,
    ) -> Result<Daemon, MementoError> {
        if options.token.is_none() {
            if let Transport::Tcp { bind } = client_transport {
                return Err(MementoError::ipc(format!(
                    "refusing to serve clients on tcp {bind} without a token"
                )));
            }
        }
        for sub in ["store", "runs", "pending"] {
            std::fs::create_dir_all(options.root.join(sub))
                .map_err(|e| MementoError::storage(format!("create daemon root: {e}")))?;
        }
        let store = ResultStore::open(options.root.join("store"))
            .map_err(|e| MementoError::storage(format!("open daemon store: {e}")))?;
        store.set_wire(options.wire);
        let cache =
            Arc::new(ResultCache::open_store(Arc::clone(&store)).storage_format(options.wire));
        let pool = WorkerPool::listen(
            worker_transport,
            PoolOptions { token: options.token.clone(), ..PoolOptions::default() },
        )?;
        let (listener, client_dir) = client_transport
            .bind()
            .map_err(|e| MementoError::ipc(format!("bind client endpoint: {e}")))?;
        let endpoint = listener.endpoint();
        let shared = Arc::new(DaemonShared {
            queue: AdmissionQueue::new(options.max_queue, options.max_in_flight),
            options,
            registry: Arc::new(registry),
            store,
            cache,
            gate: InflightGate::new(),
            pool,
            channels: Mutex::new(HashMap::new()),
            submissions: Mutex::new(HashMap::new()),
            run_joins: Mutex::new(Vec::new()),
            stop: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            started: Instant::now(),
        });
        rescan_pending(&shared);
        let acceptor = {
            let shared = Arc::clone(&shared);
            thread::Builder::new()
                .name("memento-daemon-accept".to_string())
                .spawn(move || {
                    poll_accept(listener, &shared.stop, |stream| {
                        let per_conn = Arc::clone(&shared);
                        let _ = thread::Builder::new()
                            .name("memento-daemon-session".to_string())
                            .spawn(move || session::handle(per_conn, stream));
                    });
                })
                .map_err(|e| MementoError::ipc(format!("spawn acceptor: {e}")))?
        };
        let scheduler = {
            let shared = Arc::clone(&shared);
            thread::Builder::new()
                .name("memento-daemon-sched".to_string())
                .spawn(move || {
                    while !shared.stopping() {
                        if !shared.draining() {
                            if let Some(run_id) = shared.queue.next_ready() {
                                launch_run(&shared, run_id);
                                continue;
                            }
                        }
                        thread::sleep(SCHED_POLL);
                    }
                })
                .map_err(|e| MementoError::ipc(format!("spawn scheduler: {e}")))?
        };
        Ok(Daemon {
            shared,
            endpoint,
            acceptor: Some(acceptor),
            scheduler: Some(scheduler),
            _client_dir: client_dir,
        })
    }

    /// The client (submit/attach/status) endpoint.
    pub fn endpoint(&self) -> &Endpoint {
        &self.endpoint
    }

    /// The worker-registration endpoint (hand this to `memento serve`).
    pub fn worker_endpoint(&self) -> Endpoint {
        self.shared.pool.endpoint().clone()
    }

    /// Requests a drain, identical to receiving a wire `Shutdown` frame:
    /// queued runs stay pending on disk, in-flight runs are cancelled
    /// (finished attempts persist, the rest journal as skipped).
    pub fn shutdown(&self) {
        self.shared.begin_drain();
    }

    /// The current status document (same shape the wire status channel
    /// serves).
    pub fn status(&self) -> Json {
        self.shared.status_doc()
    }

    /// Blocks until a drain has been requested *and* every running run
    /// has finished, then stops all daemon threads, shuts the worker
    /// pool down, and seals the store's active segment.
    pub fn wait(mut self) {
        loop {
            if self.shared.draining() && self.shared.queue.running() == 0 {
                break;
            }
            thread::sleep(Duration::from_millis(20));
        }
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        let joins = std::mem::take(&mut *self.shared.run_joins.lock().unwrap());
        for h in joins {
            let _ = h.join();
        }
        self.shared.pool.shutdown();
        let _ = self.shared.store.seal_active();
    }
}

/// Re-admits every `pending/*.json` submission (sorted by filename for a
/// deterministic post-restart order). Completed cells restore from the
/// shared cache when these runs re-execute, so resumption neither loses
/// nor duplicates outcomes.
fn rescan_pending(shared: &Arc<DaemonShared>) {
    let dir = shared.options.root.join("pending");
    let Ok(mut files) = mfs::list_files_with_ext(&dir, "json") else {
        return;
    };
    files.sort();
    for path in files {
        let Some((run_id, sub)) = parse_pending(&path) else {
            continue;
        };
        let tenant = sub.tenant.clone();
        shared.install_run(&run_id, sub);
        if shared.queue.admit(&run_id, &tenant).is_err() {
            shared.uninstall_run(&run_id);
        }
    }
}

/// Parses one pending file back into its run id + submission.
fn parse_pending(path: &Path) -> Option<(String, ParsedSubmission)> {
    let doc = json::parse(&mfs::read_string(path).ok()?).ok()?;
    let run_id = doc.get("run_id")?.as_str()?.to_string();
    let tenant = doc.get("tenant")?.as_str()?.to_string();
    let matrix = crate::config::loader::from_json(doc.get("matrix")?).ok()?;
    let exp = doc.get("exp").and_then(|e| e.as_str()).map(str::to_string);
    let version = doc.get("version").and_then(|v| v.as_str()).map(str::to_string);
    let seed = doc.get("seed").and_then(|s| s.as_str()).and_then(|s| s.parse().ok())?;
    Some((run_id, ParsedSubmission { tenant, matrix, exp, version, seed }))
}

/// Terminal event kinds retained in the replay history and persisted to
/// `events.jsonl`; everything else is live-only stream chatter.
fn retain_kind(kind: &str) -> bool {
    matches!(kind, "task_finished" | "worker_crashed" | "run_complete")
}

/// Launches one admitted run on the shared pool and spawns its drain
/// thread (event tee: channel + `events.jsonl`).
fn launch_run(shared: &Arc<DaemonShared>, run_id: String) {
    let Some(sub) = shared.take_submission(&run_id) else {
        // Unlaunchable (lost submission — should not happen); release
        // the quota slot rather than leak a permanently-running row.
        shared.queue.finish(&run_id, false);
        return;
    };
    let channel = shared.channel(&run_id).unwrap_or_else(RunChannel::new);
    let run_dir = shared.run_dir(&run_id);
    if let Err(e) = std::fs::create_dir_all(&run_dir) {
        fail_launch(shared, &run_id, &channel, format!("create run dir: {e}"));
        return;
    }
    let mut memento = Memento::with_registry((*shared.registry).clone())
        .with_store(Arc::clone(&shared.store))
        .with_cache(Arc::clone(&shared.cache))
        .with_inflight_gate(Arc::clone(&shared.gate))
        .run_label(run_id.clone())
        .with_journal(run_dir.join("journal.jsonl"))
        .trace_to(run_dir.join("trace"))
        .wire_format(shared.options.wire)
        .seed(sub.seed)
        .version(sub.version.clone().unwrap_or_else(|| shared.options.version.clone()))
        .with_worker_pool(Arc::clone(&shared.pool))
        .remote_workers(shared.pool.endpoint().to_string(), shared.options.workers_per_run);
    if let Some(exp) = &sub.exp {
        memento = memento.exp(exp.clone());
    }
    if let Some(budget) = shared.options.task_timeout {
        memento = memento.task_timeout(budget);
    }
    let run = match memento.launch(&sub.matrix) {
        Ok(run) => run,
        Err(e) => {
            fail_launch(shared, &run_id, &channel, format!("launch failed: {e}"));
            return;
        }
    };
    let drain_shared = Arc::clone(shared);
    let join = thread::Builder::new().name("memento-daemon-drain".to_string()).spawn(move || {
        let shared = drain_shared;
        let events_path = run_dir.join("events.jsonl");
        let mut events_file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&events_path)
            .ok();
        let mut cancelled = false;
        loop {
            if shared.draining() && !cancelled {
                run.cancel();
                cancelled = true;
            }
            while let Some(ev) = run.try_event() {
                handle_event(&shared, &run_id, &channel, &mut events_file, ev);
            }
            if run.is_finished() {
                while let Some(ev) = run.try_event() {
                    handle_event(&shared, &run_id, &channel, &mut events_file, ev);
                }
                break;
            }
            thread::sleep(DRAIN_POLL);
        }
        // Belt-and-braces: if the run thread died without a RunComplete
        // (panic), still release the quota slot and close the channel.
        if shared.queue.phase(&run_id) == Some(crate::daemon::queue::RunPhase::Running) {
            shared.queue.finish(&run_id, false);
        }
        channel.finish();
        shared.retire_finished();
    });
    if let Ok(join) = join {
        shared.run_joins.lock().unwrap().push(join);
    }
}

/// Publishes a synthetic `run_error` terminal event and settles queue +
/// pending-file state for a run that never launched.
fn fail_launch(shared: &Arc<DaemonShared>, run_id: &str, channel: &Arc<RunChannel>, msg: String) {
    channel.publish(
        Json::obj(vec![("event", Json::str("run_error")), ("message", Json::str(msg))]),
        true,
    );
    channel.finish();
    shared.queue.finish(run_id, false);
    shared.remove_pending(run_id);
    shared.retire_finished();
}

/// Tees one run event into the fan-out channel and (for terminal kinds)
/// `events.jsonl`, and settles queue/pending state on `RunComplete`.
fn handle_event(
    shared: &Arc<DaemonShared>,
    run_id: &str,
    channel: &Arc<RunChannel>,
    events_file: &mut Option<std::fs::File>,
    ev: RunEvent,
) {
    let doc = ev.to_json();
    let kind = doc.get("event").and_then(|k| k.as_str()).unwrap_or("").to_string();
    let retain = retain_kind(&kind);
    if retain {
        if let Some(f) = events_file {
            let _ = writeln!(f, "{doc}");
        }
    }
    channel.publish(doc, retain);
    if let RunEvent::RunComplete(summary) = &ev {
        let ok = summary.failed == 0 && !summary.aborted && !summary.cancelled;
        shared.queue.finish(run_id, ok);
        if !summary.cancelled {
            // Cancelled (drained) runs keep their pending file: a
            // restarted daemon re-admits them and the shared cache
            // restores whatever already finished.
            shared.remove_pending(run_id);
        }
        channel.finish();
    }
}
