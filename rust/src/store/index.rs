//! Sharded in-memory index over the segment log.
//!
//! Same shape as the result cache's memory tier: 16 FNV-1a shards, so the
//! design that already serves warm cache hits generalizes directly to
//! "where on disk does key X live". The index is *derived* state — it is
//! rebuilt on open by replaying segment record headers in log order
//! (later records supersede earlier ones), which is also what makes
//! compaction crash-safe: any mix of pre- and post-compaction segment
//! files replays to the same live set.
//!
//! Alongside the key → location map the index keeps:
//! - a **content-hash table** (SHA-256 of each stored value) counting
//!   cross-run dedup hits — two runs that produce identical values are
//!   visible as dedup even though each record stays self-contained;
//! - **latest-action tombstones**: an invalidated key's tombstone must
//!   survive compaction for as long as it is the newest action for that
//!   key, otherwise a crash that leaves an older segment behind would
//!   resurrect the invalidated record on replay;
//! - **per-segment live/dead counters** driving the compaction trigger
//!   and `memento status --store`.

use std::collections::{BTreeMap, HashMap};

/// Number of index shards (matches the result cache's memory tier).
pub const SHARDS: usize = 16;

/// Where a record lives: which segment, at what frame offset, and how
/// long its body is (the length is re-verified against the frame header
/// on every read).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Loc {
    /// Segment id (`seg-NNNNNN.log`).
    pub segment: u64,
    /// Frame start offset within the segment file.
    pub offset: u64,
    /// Record body length in bytes (excluding the 8-byte frame header).
    pub body_len: u32,
}

/// Per-segment record accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SegmentStat {
    /// Records appended to this segment (indexed kinds only).
    pub total: u64,
    /// Records in this segment that have since been superseded or
    /// invalidated — reclaimable by compaction.
    pub dead: u64,
}

/// FNV-1a shard selector (identical constants to the cache's memory tier).
pub fn shard_of(key: &str) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % SHARDS as u64) as usize
}

/// The in-memory index: key → [`Loc`] plus the bookkeeping described in
/// the module docs. Not internally synchronized — the store wraps it in
/// its own mutex.
pub struct ShardedIndex {
    shards: Vec<HashMap<String, Loc>>,
    tombstones: HashMap<String, Loc>,
    hashes: HashMap<String, u64>,
    dedup_hits: u64,
    segments: BTreeMap<u64, SegmentStat>,
}

impl ShardedIndex {
    /// An empty index.
    pub fn new() -> ShardedIndex {
        ShardedIndex {
            shards: (0..SHARDS).map(|_| HashMap::new()).collect(),
            tombstones: HashMap::new(),
            hashes: HashMap::new(),
            dedup_hits: 0,
            segments: BTreeMap::new(),
        }
    }

    /// Applies a put of `key` at `loc`. Any previous location (or
    /// pending tombstone) for the key becomes dead.
    pub fn record_put(&mut self, key: String, loc: Loc) {
        self.segments.entry(loc.segment).or_default().total += 1;
        if let Some(tomb) = self.tombstones.remove(&key) {
            self.mark_dead(tomb.segment);
        }
        if let Some(old) = self.shards[shard_of(&key)].insert(key, loc) {
            self.mark_dead(old.segment);
        }
    }

    /// Applies a tombstone for `key` written at `loc`: the key leaves the
    /// live map, its old record becomes dead, and the tombstone itself is
    /// retained as the key's latest action (see module docs for why).
    pub fn record_tombstone(&mut self, key: String, loc: Loc) {
        self.segments.entry(loc.segment).or_default().total += 1;
        if let Some(old) = self.shards[shard_of(&key)].remove(&key) {
            self.mark_dead(old.segment);
        }
        if let Some(prev) = self.tombstones.insert(key, loc) {
            self.mark_dead(prev.segment);
        }
    }

    /// Notes a stored value's content hash; returns `true` when the same
    /// hash was already present (a cross-run dedup hit).
    pub fn note_hash(&mut self, hash: &str) -> bool {
        let n = self.hashes.entry(hash.to_string()).or_insert(0);
        *n += 1;
        if *n > 1 {
            self.dedup_hits += 1;
            true
        } else {
            false
        }
    }

    /// Live location of `key`, if any.
    pub fn get(&self, key: &str) -> Option<Loc> {
        self.shards[shard_of(key)].get(key).copied()
    }

    /// Number of live keys.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    /// True when no key is live.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.is_empty())
    }

    /// Live keys per shard — the occupancy picture for `status --store`.
    pub fn shard_occupancy(&self) -> [usize; SHARDS] {
        let mut out = [0usize; SHARDS];
        for (i, s) in self.shards.iter().enumerate() {
            out[i] = s.len();
        }
        out
    }

    /// All live `(key, loc)` pairs whose key starts with `prefix`
    /// (checkpoint resume and queries use key namespaces as prefixes).
    pub fn entries_with_prefix(&self, prefix: &str) -> Vec<(String, Loc)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            for (k, loc) in shard {
                if k.starts_with(prefix) {
                    out.push((k.clone(), *loc));
                }
            }
        }
        out
    }

    /// Live entries located in any of `segments` — the records a
    /// compaction of those segments must carry forward.
    pub fn live_in_segments(&self, segments: &[u64]) -> Vec<(String, Loc)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            for (k, loc) in shard {
                if segments.contains(&loc.segment) {
                    out.push((k.clone(), *loc));
                }
            }
        }
        out
    }

    /// Latest-action tombstones located in any of `segments` — these must
    /// also be carried forward by compaction (module docs).
    pub fn tombstones_in_segments(&self, segments: &[u64]) -> Vec<(String, Loc)> {
        self.tombstones
            .iter()
            .filter(|(_, loc)| segments.contains(&loc.segment))
            .map(|(k, loc)| (k.clone(), *loc))
            .collect()
    }

    /// Per-segment accounting for `segment`, zeroed if never seen.
    pub fn segment_stat(&self, segment: u64) -> SegmentStat {
        self.segments.get(&segment).copied().unwrap_or_default()
    }

    /// Total dead (reclaimable) records across all segments.
    pub fn dead_records(&self) -> u64 {
        self.segments.values().map(|s| s.dead).sum()
    }

    /// Total records replayed into the index (all segments, all kinds
    /// that are indexed).
    pub fn total_records(&self) -> u64 {
        self.segments.values().map(|s| s.total).sum()
    }

    /// Cross-run dedup hits observed (puts whose value hash was already
    /// in the store).
    pub fn dedup_hits(&self) -> u64 {
        self.dedup_hits
    }

    fn mark_dead(&mut self, segment: u64) {
        self.segments.entry(segment).or_default().dead += 1;
    }
}

impl Default for ShardedIndex {
    fn default() -> ShardedIndex {
        ShardedIndex::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loc(segment: u64, offset: u64) -> Loc {
        Loc { segment, offset, body_len: 10 }
    }

    #[test]
    fn put_get_supersede() {
        let mut ix = ShardedIndex::new();
        ix.record_put("r:a".into(), loc(1, 0));
        ix.record_put("r:b".into(), loc(1, 18));
        assert_eq!(ix.get("r:a"), Some(loc(1, 0)));
        assert_eq!(ix.len(), 2);
        assert_eq!(ix.dead_records(), 0);
        // Supersede a: its old record becomes dead.
        ix.record_put("r:a".into(), loc(2, 0));
        assert_eq!(ix.get("r:a"), Some(loc(2, 0)));
        assert_eq!(ix.len(), 2);
        assert_eq!(ix.segment_stat(1).dead, 1);
        assert_eq!(ix.segment_stat(1).total, 2);
        assert_eq!(ix.segment_stat(2).total, 1);
    }

    #[test]
    fn tombstone_lifecycle() {
        let mut ix = ShardedIndex::new();
        ix.record_put("r:a".into(), loc(1, 0));
        ix.record_tombstone("r:a".into(), loc(2, 0));
        assert_eq!(ix.get("r:a"), None);
        assert_eq!(ix.segment_stat(1).dead, 1);
        // The tombstone is the latest action: it must be carried forward.
        assert_eq!(ix.tombstones_in_segments(&[2]).len(), 1);
        // A re-put supersedes the tombstone, which becomes dead.
        ix.record_put("r:a".into(), loc(3, 0));
        assert_eq!(ix.get("r:a"), Some(loc(3, 0)));
        assert!(ix.tombstones_in_segments(&[2]).is_empty());
        assert_eq!(ix.segment_stat(2).dead, 1);
    }

    #[test]
    fn hash_table_counts_dedup() {
        let mut ix = ShardedIndex::new();
        assert!(!ix.note_hash("h1"));
        assert!(ix.note_hash("h1"));
        assert!(!ix.note_hash("h2"));
        assert!(ix.note_hash("h1"));
        assert_eq!(ix.dedup_hits(), 2);
    }

    #[test]
    fn prefix_and_segment_listings() {
        let mut ix = ShardedIndex::new();
        ix.record_put("r:x".into(), loc(1, 0));
        ix.record_put("c:run1:x".into(), loc(1, 30));
        ix.record_put("c:run2:x".into(), loc(2, 0));
        ix.record_put("m:run1".into(), loc(1, 60));
        assert_eq!(ix.entries_with_prefix("c:run1:").len(), 1);
        assert_eq!(ix.entries_with_prefix("r:").len(), 1);
        let mut in_seg1 = ix.live_in_segments(&[1]);
        in_seg1.sort_by(|a, b| a.0.cmp(&b.0));
        let keys: Vec<&str> = in_seg1.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["c:run1:x", "m:run1", "r:x"]);
    }

    #[test]
    fn occupancy_spreads_across_shards() {
        let mut ix = ShardedIndex::new();
        for i in 0..256 {
            ix.record_put(format!("r:{i:064x}"), loc(1, i * 20));
        }
        let occ = ix.shard_occupancy();
        assert_eq!(occ.iter().sum::<usize>(), 256);
        // FNV over distinct keys should touch every shard at this count.
        assert!(occ.iter().all(|&n| n > 0), "{occ:?}");
    }
}
