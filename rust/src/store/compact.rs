//! Compaction: folds sealed segments down to their live records.
//!
//! The append-only log trades write simplicity for accumulating dead
//! records (superseded puts, invalidated entries). Compaction reclaims
//! them by rewriting all sealed segments into one new segment containing
//! only what must survive:
//!
//! - every **live** record located in the input segments,
//! - every **latest-action tombstone** located there (dropping a
//!   tombstone while any older segment could still resurface its key
//!   would un-invalidate that key on replay — see [`super::index`]),
//! - fresh **run registration** records preserving run recency order.
//!
//! ## Crash-safety protocol
//!
//! 1. Write the survivor records to `compact.tmp` (same directory),
//!    footer-sealed, and fsync it.
//! 2. Atomically rename `compact.tmp` over the **highest-numbered input
//!    segment** and fsync the directory.
//! 3. Unlink the lower-numbered input segments, then fsync the directory.
//!
//! The invariant making every intermediate state safe: replay applies
//! segments in id order and later records supersede earlier ones, so any
//! mix of "old segments still present" and "compacted segment in place"
//! replays to exactly the live set — leftover old records are shadowed by
//! the compacted copies in the higher-numbered segment. A crash before
//! step 2 leaves only ignorable `compact.tmp` debris; a crash during
//! step 3 leaves shadowed duplicates that the *next* compaction reclaims.
//! Zero live records are lost at any point (asserted step-by-step in the
//! kill-during-compaction tests below).

use super::index::Loc;
use super::{scan_dir, segment, Inner, ResultStore};
use crate::util::codec;
use crate::util::fs as mfs;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fs;
use std::io::{self, Write};
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Outcome of one [`ResultStore::compact`] pass.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CompactReport {
    /// Sealed segments folded (0 when there was nothing to do or a pass
    /// was already running).
    pub input_segments: usize,
    /// Live records carried into the compacted segment.
    pub live_carried: usize,
    /// Latest-action tombstones carried forward.
    pub tombstones_carried: usize,
    /// Dead records dropped (reclaimed).
    pub records_dropped: u64,
    /// Input bytes before folding.
    pub bytes_before: u64,
    /// Compacted segment size.
    pub bytes_after: u64,
    /// True when another pass was already in flight and this one skipped.
    pub skipped: bool,
    /// True when a test-injected abort stopped the pass mid-protocol.
    pub aborted: bool,
}

/// Test-injection points simulating a crash mid-compaction. After an
/// aborted pass the in-memory store is stale by design (a real crash
/// loses it anyway) — the store handle must be discarded and the
/// directory reopened, which is exactly what the tests do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum AbortPoint {
    /// Crash after `compact.tmp` is written+synced, before the rename.
    AfterTmpWrite,
    /// Crash after the rename over the last input segment.
    AfterRename,
    /// Crash after unlinking `n + 1` of the lower-numbered inputs.
    AfterUnlink(usize),
}

/// Compaction trigger: at least two sealed segments and at least half of
/// their records dead.
pub(crate) fn should_compact(inner: &Inner) -> bool {
    if inner.sealed.len() < 2 {
        return false;
    }
    let (mut total, mut dead) = (0u64, 0u64);
    for id in &inner.sealed {
        let s = inner.index.segment_stat(*id);
        total += s.total;
        dead += s.dead;
    }
    dead > 0 && dead * 2 >= total
}

impl ResultStore {
    /// Folds all sealed segments into one, dropping superseded and
    /// invalidated records. Safe to call at any time; a no-op when there
    /// are no sealed segments or another pass is already running.
    pub fn compact(&self) -> io::Result<CompactReport> {
        self.compact_with_abort(None)
    }

    /// Kicks a compaction pass on a background thread (the auto-trigger
    /// path). Returns `false` when a pass is already in flight or the
    /// thread could not be spawned.
    pub fn compact_in_background(self: &Arc<Self>) -> bool {
        if self.compacting.load(Ordering::SeqCst) {
            return false;
        }
        let me = Arc::clone(self);
        std::thread::Builder::new()
            .name("memento-store-compact".to_string())
            .spawn(move || {
                let _ = me.compact();
            })
            .is_ok()
    }

    pub(crate) fn compact_with_abort(&self, abort: Option<AbortPoint>) -> io::Result<CompactReport> {
        if self.compacting.swap(true, Ordering::SeqCst) {
            return Ok(CompactReport { skipped: true, ..CompactReport::default() });
        }
        let result = self.compact_inner(abort);
        self.compacting.store(false, Ordering::SeqCst);
        result
    }

    fn compact_inner(&self, abort: Option<AbortPoint>) -> io::Result<CompactReport> {
        let mut inner = self.lock();
        let mut inputs = inner.sealed.clone();
        inputs.sort_unstable();
        if inputs.is_empty() {
            return Ok(CompactReport::default());
        }
        let mut report = CompactReport { input_segments: inputs.len(), ..CompactReport::default() };

        // Survivors, grouped per input segment in (segment, offset) order
        // so each input file is read exactly once, sequentially.
        let live = inner.index.live_in_segments(&inputs);
        let tombs = inner.index.tombstones_in_segments(&inputs);
        report.live_carried = live.len();
        report.tombstones_carried = tombs.len();
        let mut by_seg: BTreeMap<u64, Vec<Loc>> = BTreeMap::new();
        for (_, loc) in live.iter().chain(tombs.iter()) {
            by_seg.entry(loc.segment).or_default().push(*loc);
        }

        // Step 1: write survivors to compact.tmp, sealed, fsynced.
        let tmp = inner.dir.join("compact.tmp");
        let mut out = fs::File::create(&tmp)?;
        let mut carried = 0u64;
        for run in &inner.runs {
            let doc = Json::obj(vec![("kind", Json::str("run")), ("run", Json::str(run))]);
            out.write_all(&segment::encode_frame(&codec::write_document(&doc, inner.wire)))?;
            carried += 1;
        }
        for id in &inputs {
            let path = segment::segment_path(&inner.dir, *id);
            report.bytes_before += fs::metadata(&path)?.len();
            let Some(locs) = by_seg.get_mut(id) else { continue };
            locs.sort_unstable_by_key(|l| l.offset);
            let bytes = fs::read(&path)?;
            for loc in locs.iter() {
                let start = loc.offset as usize;
                let end = start + segment::FRAME_HEADER as usize + loc.body_len as usize;
                let frame = bytes.get(start..end).ok_or_else(|| {
                    io::Error::other(format!("segment {id:06}: index loc out of bounds"))
                })?;
                out.write_all(frame)?;
                carried += 1;
            }
        }
        let seal = Json::obj(vec![
            ("kind", Json::str("seal")),
            ("records", Json::int(carried as i64 + 1)),
        ]);
        out.write_all(&segment::encode_frame(&codec::write_document(&seal, inner.wire)))?;
        out.sync_all()?;
        report.bytes_after = out.metadata()?.len();
        drop(out);
        if abort == Some(AbortPoint::AfterTmpWrite) {
            report.aborted = true;
            return Ok(report);
        }

        // Step 2: atomic rename over the highest-numbered input, then
        // make the rename itself durable.
        let target = *inputs.last().unwrap();
        fs::rename(&tmp, segment::segment_path(&inner.dir, target))?;
        mfs::sync_dir(&inner.dir)?;
        if abort == Some(AbortPoint::AfterRename) {
            report.aborted = true;
            return Ok(report);
        }

        // Step 3: unlink the shadowed lower-numbered inputs.
        for (i, id) in inputs[..inputs.len() - 1].iter().enumerate() {
            fs::remove_file(segment::segment_path(&inner.dir, *id))?;
            if abort == Some(AbortPoint::AfterUnlink(i)) {
                report.aborted = true;
                return Ok(report);
            }
        }
        mfs::sync_dir(&inner.dir)?;

        // Refresh in-memory state from the folded layout (replay is the
        // single source of truth — the same code path open() trusts).
        let before_dead = inner.index.dead_records();
        let st = scan_dir(&inner.dir)?;
        report.records_dropped = before_dead.saturating_sub(st.index.dead_records());
        inner.index = st.index;
        inner.sealed = st.sealed;
        inner.runs = st.runs;
        inner.warnings.extend(st.warnings);
        inner.compactions += 1;
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;
    use std::collections::HashMap;

    fn value(v: f64) -> Json {
        Json::obj(vec![("score", Json::Num(v))])
    }

    /// Builds a store with several sealed segments, overwrites and
    /// invalidations included; returns the expected live map.
    fn build_store(td: &TempDir) -> HashMap<String, Option<Json>> {
        let store = ResultStore::open(td.path()).unwrap();
        store.set_auto_compact(false);
        store.set_segment_max(400);
        store.begin_run("first").unwrap();
        for i in 0..24 {
            store.put_result(&format!("id{i:02}"), &Json::Null, &value(i as f64)).unwrap();
        }
        store.begin_run("second").unwrap();
        // Overwrite half (old versions become dead)…
        for i in 0..12 {
            store.put_result(&format!("id{i:02}"), &Json::Null, &value(100.0 + i as f64)).unwrap();
        }
        // …and invalidate a few (latest action = tombstone).
        for i in 20..24 {
            store.invalidate_result(&format!("id{i:02}")).unwrap();
        }
        store.seal_active().unwrap();
        store.sync().unwrap();
        assert!(store.stats().sealed_segments >= 3, "{:?}", store.stats());

        let mut expected = HashMap::new();
        for i in 0..24 {
            let id = format!("id{i:02}");
            expected.insert(
                id,
                if i >= 20 {
                    None
                } else if i < 12 {
                    Some(value(100.0 + i as f64))
                } else {
                    Some(value(i as f64))
                },
            );
        }
        expected
    }

    fn assert_live_set(store: &ResultStore, expected: &HashMap<String, Option<Json>>) {
        for (id, want) in expected {
            assert_eq!(&store.get_result(id).unwrap(), want, "id {id}");
        }
    }

    #[test]
    fn full_compaction_reclaims_dead_and_preserves_live() {
        let td = TempDir::new("compact-full").unwrap();
        let expected = build_store(&td);
        let store = ResultStore::open(td.path()).unwrap();
        store.set_auto_compact(false);
        let before = store.stats();
        assert!(before.dead_records > 0);
        let report = store.compact().unwrap();
        assert!(!report.skipped && !report.aborted);
        assert_eq!(report.input_segments, before.sealed_segments);
        assert_eq!(report.tombstones_carried, 4);
        assert!(report.bytes_after < report.bytes_before, "{report:?}");
        let after = store.stats();
        assert_eq!(after.sealed_segments, 1, "{after:?}");
        assert_eq!(after.dead_records, 0, "{after:?}");
        assert_eq!(after.compactions, 1);
        assert_live_set(&store, &expected);
        // Runs survive the fold.
        assert_eq!(store.runs(), vec!["first".to_string(), "second".to_string()]);
        // And the folded layout replays identically after reopen.
        drop(store);
        let store = ResultStore::open(td.path()).unwrap();
        assert!(store.open_warnings().is_empty(), "{:?}", store.open_warnings());
        assert_live_set(&store, &expected);
        assert_eq!(store.runs(), vec!["first".to_string(), "second".to_string()]);
    }

    #[test]
    fn kill_during_compaction_loses_zero_live_records() {
        // Satellite: crash at every protocol step must leave the store
        // openable with the full live set intact.
        let aborts = [
            AbortPoint::AfterTmpWrite,
            AbortPoint::AfterRename,
            AbortPoint::AfterUnlink(0),
            AbortPoint::AfterUnlink(1),
        ];
        for abort in aborts {
            let td = TempDir::new("compact-kill").unwrap();
            let expected = build_store(&td);
            {
                let store = ResultStore::open(td.path()).unwrap();
                store.set_auto_compact(false);
                let report = store.compact_with_abort(Some(abort)).unwrap();
                assert!(report.aborted, "{abort:?}");
                // Simulated crash: the handle is discarded, state on disk
                // is whatever the abort point left behind.
            }
            let store = ResultStore::open(td.path()).unwrap();
            assert_live_set(&store, &expected);
            assert_eq!(
                store.runs(),
                vec!["first".to_string(), "second".to_string()],
                "{abort:?}"
            );
            // The interrupted pass is recoverable: a clean compaction
            // afterwards fully reclaims.
            store.set_auto_compact(false);
            let report = store.compact().unwrap();
            assert!(!report.aborted, "{abort:?}");
            assert_eq!(store.stats().dead_records, 0, "{abort:?}");
            assert_live_set(&store, &expected);
            // Store stays writable after recovery.
            store.put_result("fresh", &Json::Null, &value(7.0)).unwrap();
            assert_eq!(store.get_result("fresh").unwrap(), Some(value(7.0)));
        }
    }

    #[test]
    fn compaction_trigger_thresholds() {
        let td = TempDir::new("compact-trig").unwrap();
        let store = ResultStore::open(td.path()).unwrap();
        store.set_auto_compact(false);
        store.set_segment_max(300);
        for i in 0..8 {
            store.put_result(&format!("k{i}"), &Json::Null, &value(i as f64)).unwrap();
        }
        store.seal_active().unwrap();
        {
            let inner = store.lock();
            assert!(!should_compact(&inner), "no dead records yet");
        }
        for i in 0..8 {
            store.put_result(&format!("k{i}"), &Json::Null, &value(50.0 + i as f64)).unwrap();
        }
        store.seal_active().unwrap();
        {
            let inner = store.lock();
            assert!(should_compact(&inner), "half the sealed records are dead");
        }
    }

    #[test]
    fn concurrent_passes_skip() {
        let td = TempDir::new("compact-skip").unwrap();
        build_store(&td);
        let store = ResultStore::open(td.path()).unwrap();
        store.compacting.store(true, Ordering::SeqCst);
        let report = store.compact().unwrap();
        assert!(report.skipped);
        store.compacting.store(false, Ordering::SeqCst);
        assert!(!store.compact().unwrap().skipped);
    }

    #[test]
    fn compacting_empty_store_is_noop() {
        let td = TempDir::new("compact-empty").unwrap();
        let store = ResultStore::open(td.path()).unwrap();
        let report = store.compact().unwrap();
        assert_eq!(report, CompactReport::default());
    }
}
