//! Segment files: the append-only on-disk unit of the result store.
//!
//! A store directory holds numbered segment files (`seg-000001.log`,
//! `seg-000002.log`, …). Exactly one — the highest-numbered — is *active*
//! and accepts appends; every lower-numbered segment is *sealed*
//! (terminated by a `seal` footer record) and immutable, which is what
//! makes compaction able to read them without coordination.
//!
//! ## Frame format
//!
//! Each record is one frame:
//!
//! ```text
//! ┌────────────┬────────────┬───────────────────────────────┐
//! │ len: u32LE │ crc: u32LE │ body: len bytes               │
//! └────────────┴────────────┴───────────────────────────────┘
//! ```
//!
//! `crc` is the CRC-32 ([`crate::util::crc32`]) of the body, and the body
//! is one codec document ([`crate::util::codec::write_document`] output,
//! binary or JSON — readers auto-detect per record). A torn append —
//! crash mid-write — leaves a frame whose length or CRC does not check
//! out; [`RecordScan`] stops there and reports the damage instead of
//! decoding garbage, and the store truncates the tail and keeps going.

use crate::util::crc32::crc32;
use crate::util::fs as mfs;
use std::fs::{self, File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Bytes of frame header (`len` + `crc`) preceding each record body.
pub const FRAME_HEADER: u64 = 8;

/// Upper bound on a single record body. A corrupt length prefix must not
/// make a reader attempt a multi-gigabyte allocation; anything above this
/// is treated as tail damage.
pub const MAX_BODY: u32 = 64 << 20;

/// File name for segment `id` (`seg-000001.log` style; the fixed-width
/// zero padding makes lexicographic directory order equal numeric order).
pub fn segment_file_name(id: u64) -> String {
    format!("seg-{id:06}.log")
}

/// Full path of segment `id` inside `dir`.
pub fn segment_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(segment_file_name(id))
}

/// Parses a segment id back out of a path; `None` for non-segment files.
pub fn parse_segment_id(path: &Path) -> Option<u64> {
    let name = path.file_name()?.to_str()?;
    let rest = name.strip_prefix("seg-")?.strip_suffix(".log")?;
    rest.parse().ok()
}

/// All segment files in `dir`, as `(id, path)` sorted by id.
pub fn list_segments(dir: &Path) -> io::Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    for p in mfs::list_files_with_ext(dir, "log")? {
        if let Some(id) = parse_segment_id(&p) {
            out.push((id, p));
        }
    }
    out.sort();
    Ok(out)
}

/// Encodes one record body as a framed byte sequence (header + body).
pub fn encode_frame(body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER as usize + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(body).to_le_bytes());
    out.extend_from_slice(body);
    out
}

/// Reads and CRC-verifies a single record body at a known offset.
/// `body_len` is the length the index recorded at append time; a mismatch
/// means the file changed underneath the index and is reported as
/// corruption, not silently accepted.
pub fn read_record(path: &Path, offset: u64, body_len: u32) -> io::Result<Vec<u8>> {
    let mut f = File::open(path)?;
    f.seek(SeekFrom::Start(offset))?;
    let mut header = [0u8; FRAME_HEADER as usize];
    f.read_exact(&mut header)?;
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap());
    let crc = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if len != body_len {
        return Err(io::Error::other(format!(
            "record at {}:{offset}: length {len} != indexed {body_len}",
            path.display()
        )));
    }
    let mut body = vec![0u8; len as usize];
    f.read_exact(&mut body)?;
    if crc32(&body) != crc {
        return Err(io::Error::other(format!(
            "record at {}:{offset}: crc mismatch",
            path.display()
        )));
    }
    Ok(body)
}

/// Why a [`RecordScan`] stopped before the end of the segment bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TailDamage {
    /// Byte offset of the first frame that failed validation.
    pub at: u64,
    /// Human-readable description of the failure.
    pub reason: String,
}

/// Iterator over the valid frames of a segment's bytes, yielding
/// `(frame_offset, body)`. Stops at the first invalid frame (truncated
/// header/body, implausible length, CRC mismatch) and records it as
/// [`RecordScan::damage`]; [`RecordScan::valid_len`] is then the length
/// of the intact prefix, i.e. the safe truncation point for re-opening
/// the segment for appends.
pub struct RecordScan<'a> {
    bytes: &'a [u8],
    pos: usize,
    damage: Option<TailDamage>,
}

impl<'a> RecordScan<'a> {
    /// Starts a scan over a whole segment's bytes.
    pub fn new(bytes: &'a [u8]) -> RecordScan<'a> {
        RecordScan { bytes, pos: 0, damage: None }
    }

    /// The damage that stopped the scan, if any. Meaningful once the
    /// iterator has returned `None`.
    pub fn damage(&self) -> Option<&TailDamage> {
        self.damage.as_ref()
    }

    /// Bytes covered by valid frames so far.
    pub fn valid_len(&self) -> u64 {
        self.pos as u64
    }

    fn fail(&mut self, at: usize, reason: impl Into<String>) -> Option<(u64, &'a [u8])> {
        self.damage = Some(TailDamage { at: at as u64, reason: reason.into() });
        None
    }
}

impl<'a> Iterator for RecordScan<'a> {
    type Item = (u64, &'a [u8]);

    fn next(&mut self) -> Option<(u64, &'a [u8])> {
        if self.damage.is_some() || self.pos == self.bytes.len() {
            return None;
        }
        let start = self.pos;
        let header_end = start + FRAME_HEADER as usize;
        if header_end > self.bytes.len() {
            return self.fail(start, "truncated frame header");
        }
        let len = u32::from_le_bytes(self.bytes[start..start + 4].try_into().unwrap());
        let crc = u32::from_le_bytes(self.bytes[start + 4..header_end].try_into().unwrap());
        if len > MAX_BODY {
            return self.fail(start, format!("implausible record length {len}"));
        }
        let body_end = header_end + len as usize;
        if body_end > self.bytes.len() {
            return self.fail(start, "truncated record body");
        }
        let body = &self.bytes[header_end..body_end];
        if crc32(body) != crc {
            return self.fail(start, "record crc mismatch");
        }
        self.pos = body_end;
        Some((start as u64, body))
    }
}

/// Append handle for the active segment.
pub struct SegmentWriter {
    file: File,
    id: u64,
    offset: u64,
    records: u64,
}

impl SegmentWriter {
    /// Creates a fresh segment `id` in `dir` (truncating any leftover
    /// file with the same name — callers only create ids above the
    /// highest existing one, so a leftover can only be pre-crash junk).
    pub fn create(dir: &Path, id: u64) -> io::Result<SegmentWriter> {
        let file = OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(segment_path(dir, id))?;
        Ok(SegmentWriter { file, id, offset: 0, records: 0 })
    }

    /// Re-opens an existing unsealed segment for further appends,
    /// truncating it to `valid_len` first (dropping any damaged tail —
    /// the caller has already scanned and warned).
    pub fn open_tail(
        dir: &Path,
        id: u64,
        valid_len: u64,
        records: u64,
    ) -> io::Result<SegmentWriter> {
        let file = OpenOptions::new().write(true).open(segment_path(dir, id))?;
        file.set_len(valid_len)?;
        let mut w = SegmentWriter { file, id, offset: valid_len, records };
        w.file.seek(SeekFrom::Start(valid_len))?;
        Ok(w)
    }

    /// This segment's id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Current end-of-file offset (where the next frame will land).
    pub fn offset(&self) -> u64 {
        self.offset
    }

    /// Number of records appended (including any pre-existing ones
    /// counted at `open_tail`).
    pub fn records(&self) -> u64 {
        self.records
    }

    /// Appends one framed record; returns the frame's start offset.
    pub fn append(&mut self, body: &[u8]) -> io::Result<u64> {
        let frame = encode_frame(body);
        self.file.write_all(&frame)?;
        let at = self.offset;
        self.offset += frame.len() as u64;
        self.records += 1;
        Ok(at)
    }

    /// Fsyncs appended data (appends themselves are not individually
    /// synced — a lost cache entry is a miss, not corruption — but flush
    /// points and seals want durability).
    pub fn sync(&mut self) -> io::Result<()> {
        self.file.sync_all()
    }

    /// Appends the seal footer record and fsyncs. After this the segment
    /// is immutable; the caller must also [`mfs::sync_dir`] if it renamed
    /// or created files as part of the same transition.
    pub fn seal(mut self, footer_body: &[u8]) -> io::Result<()> {
        self.append(footer_body)?;
        self.sync()
    }
}

/// Removes leftover temporary files (`*.tmp`) from a store directory —
/// debris from a crash mid-compaction or mid-write. Called on open.
pub fn remove_temp_files(dir: &Path) -> io::Result<()> {
    if !dir.exists() {
        return Ok(());
    }
    for entry in fs::read_dir(dir)? {
        let p = entry?.path();
        let is_tmp = p
            .file_name()
            .and_then(|n| n.to_str())
            .is_some_and(|n| n.ends_with(".tmp") || n.contains(".tmp."));
        if p.is_file() && is_tmp {
            let _ = fs::remove_file(&p);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;

    #[test]
    fn frame_roundtrip_and_point_read() {
        let td = TempDir::new("seg").unwrap();
        let mut w = SegmentWriter::create(td.path(), 1).unwrap();
        let a = w.append(b"alpha").unwrap();
        let b = w.append(b"beta-longer-body").unwrap();
        assert_eq!(a, 0);
        assert_eq!(b, FRAME_HEADER + 5);
        w.sync().unwrap();

        let path = segment_path(td.path(), 1);
        assert_eq!(read_record(&path, a, 5).unwrap(), b"alpha");
        assert_eq!(read_record(&path, b, 16).unwrap(), b"beta-longer-body");
        // Wrong indexed length is corruption, not acceptance.
        assert!(read_record(&path, a, 6).is_err());

        let bytes = fs::read(&path).unwrap();
        let mut scan = RecordScan::new(&bytes);
        let got: Vec<Vec<u8>> = scan.by_ref().map(|(_, b)| b.to_vec()).collect();
        assert_eq!(got, vec![b"alpha".to_vec(), b"beta-longer-body".to_vec()]);
        assert!(scan.damage().is_none());
        assert_eq!(scan.valid_len(), bytes.len() as u64);
    }

    #[test]
    fn truncated_tail_is_damage_not_panic() {
        let td = TempDir::new("seg-trunc").unwrap();
        let mut w = SegmentWriter::create(td.path(), 1).unwrap();
        w.append(b"keep-me").unwrap();
        w.append(b"torn-record").unwrap();
        w.sync().unwrap();
        let path = segment_path(td.path(), 1);
        let full = fs::read(&path).unwrap();
        // Cut the file anywhere inside the second frame: first record must
        // still scan, the scan must stop with damage at the second frame.
        let second_start = (FRAME_HEADER + 7) as usize;
        for cut in second_start + 1..full.len() {
            let mut scan = RecordScan::new(&full[..cut]);
            let got: Vec<_> = scan.by_ref().collect();
            assert_eq!(got.len(), 1, "cut={cut}");
            let damage = scan.damage().expect("damage reported");
            assert_eq!(damage.at, second_start as u64, "cut={cut}");
            assert_eq!(scan.valid_len(), second_start as u64);
        }
    }

    #[test]
    fn bitflip_is_detected_by_crc() {
        let td = TempDir::new("seg-flip").unwrap();
        let mut w = SegmentWriter::create(td.path(), 1).unwrap();
        w.append(b"only-record-here").unwrap();
        w.sync().unwrap();
        let path = segment_path(td.path(), 1);
        let mut bytes = fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        let mut scan = RecordScan::new(&bytes);
        assert!(scan.next().is_none());
        assert_eq!(scan.damage().unwrap().reason, "record crc mismatch");
    }

    #[test]
    fn open_tail_truncates_damage_and_appends() {
        let td = TempDir::new("seg-tail").unwrap();
        let mut w = SegmentWriter::create(td.path(), 3).unwrap();
        w.append(b"good").unwrap();
        w.sync().unwrap();
        let path = segment_path(td.path(), 3);
        // Simulate a torn append: garbage half-frame at the end.
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&[9, 0, 0]).unwrap();
        }
        let bytes = fs::read(&path).unwrap();
        let mut scan = RecordScan::new(&bytes);
        let n = scan.by_ref().count();
        assert_eq!(n, 1);
        assert!(scan.damage().is_some());
        let mut w = SegmentWriter::open_tail(td.path(), 3, scan.valid_len(), n as u64).unwrap();
        w.append(b"after-recovery").unwrap();
        w.sync().unwrap();
        let bytes = fs::read(&path).unwrap();
        let mut scan = RecordScan::new(&bytes);
        let got: Vec<Vec<u8>> = scan.by_ref().map(|(_, b)| b.to_vec()).collect();
        assert_eq!(got, vec![b"good".to_vec(), b"after-recovery".to_vec()]);
        assert!(scan.damage().is_none());
    }

    #[test]
    fn segment_names_parse_and_sort() {
        let td = TempDir::new("seg-names").unwrap();
        for id in [3u64, 1, 2] {
            SegmentWriter::create(td.path(), id).unwrap();
        }
        std::fs::write(td.join("notes.log"), b"x").unwrap();
        std::fs::write(td.join("seg-bad.log"), b"x").unwrap();
        let segs = list_segments(td.path()).unwrap();
        let ids: Vec<u64> = segs.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
        assert_eq!(parse_segment_id(&segment_path(td.path(), 42)), Some(42));
        assert_eq!(parse_segment_id(Path::new("seg-xx.log")), None);
    }

    #[test]
    fn temp_files_are_cleaned() {
        let td = TempDir::new("seg-tmp").unwrap();
        std::fs::write(td.join("compact.tmp"), b"junk").unwrap();
        std::fs::write(td.join(".seg-000001.log.tmp.123.4"), b"junk").unwrap();
        SegmentWriter::create(td.path(), 1).unwrap();
        remove_temp_files(td.path()).unwrap();
        assert!(!td.join("compact.tmp").exists());
        assert!(!td.join(".seg-000001.log.tmp.123.4").exists());
        assert!(segment_path(td.path(), 1).exists());
    }
}
