//! Cross-run queries: parameter predicates evaluated lazily over the log.
//!
//! `memento query` answers questions like *"model=svc, lr<=0.1, last 50
//! runs"* against every result record in the store. The evaluation
//! contract mirrors the scanner's ([`crate::util::scan`]): candidate
//! records are probed field-by-field with byte-wise skipping — the `run`
//! scalar for the recency filter, then individual `params` fields through
//! [`Scanner::from_raw`] — and a full [`Json`] tree is built **only for
//! records that match**, exactly once each. The thread-local
//! [`crate::util::scan::materialized_count`] therefore moves by exactly
//! the number of rows returned, which the tests assert against a
//! 10k-record store.
//!
//! Candidates come from the live index (dead and invalidated records are
//! never touched), grouped per segment so each segment file is read once,
//! sequentially, in log order.

use super::segment;
use super::ResultStore;
use crate::util::codec;
use crate::util::crc32::crc32;
use crate::util::json::Json;
use crate::util::scan::{ScanError, ScanValue, Scanner};
use std::cmp::Ordering;
use std::collections::{BTreeMap, HashSet};
use std::fs;
use std::io;

/// Comparison operator of one predicate clause.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    /// `=` — equal.
    Eq,
    /// `!=` — present, comparable, and different.
    Ne,
    /// `<` — strictly less.
    Lt,
    /// `<=` — less or equal.
    Le,
    /// `>` — strictly greater.
    Gt,
    /// `>=` — greater or equal.
    Ge,
}

impl CmpOp {
    fn accepts(self, ord: Ordering) -> bool {
        match self {
            CmpOp::Eq => ord == Ordering::Equal,
            CmpOp::Ne => ord != Ordering::Equal,
            CmpOp::Lt => ord == Ordering::Less,
            CmpOp::Le => ord != Ordering::Greater,
            CmpOp::Gt => ord == Ordering::Greater,
            CmpOp::Ge => ord != Ordering::Less,
        }
    }
}

/// A typed comparison value, inferred from the predicate text: `true`/
/// `false` → bool, numeric literals → number, anything else (optionally
/// quoted) → string.
#[derive(Debug, Clone, PartialEq)]
pub enum PredValue {
    /// Numeric comparison (integers and floats compare as `f64`).
    Num(f64),
    /// Lexicographic string comparison.
    Str(String),
    /// Boolean; only `=` and `!=` are meaningful.
    Bool(bool),
}

/// One parsed clause: `field op value`.
#[derive(Debug, Clone, PartialEq)]
pub struct Predicate {
    /// Parameter name the clause probes.
    pub field: String,
    /// Comparison operator.
    pub op: CmpOp,
    /// Typed right-hand side.
    pub value: PredValue,
}

impl Predicate {
    /// Whether a scanned parameter value satisfies this clause. A missing
    /// field or a type mismatch never matches — including for `!=`, so
    /// "lr!=0.1" means "has an lr, and it differs", not "lacks lr".
    pub fn matches(&self, v: Option<&ScanValue<'_>>) -> bool {
        let Some(v) = v else { return false };
        match &self.value {
            PredValue::Num(want) => match v.as_f64() {
                Some(have) => have.partial_cmp(want).is_some_and(|ord| self.op.accepts(ord)),
                None => false,
            },
            PredValue::Str(want) => match v.as_str() {
                Some(have) => self.op.accepts(have.cmp(want.as_str())),
                None => false,
            },
            PredValue::Bool(want) => match (v.as_bool(), self.op) {
                (Some(have), CmpOp::Eq) => have == *want,
                (Some(have), CmpOp::Ne) => have != *want,
                _ => false,
            },
        }
    }
}

/// Parses a comma-separated predicate list: `model=svc, lr<=0.1`.
/// Operators: `=`, `!=`, `<`, `<=`, `>`, `>=`. Values may be quoted to
/// force string comparison (`model="3"`). An empty input is no clauses
/// (matches everything).
pub fn parse_predicates(input: &str) -> Result<Vec<Predicate>, String> {
    let mut out = Vec::new();
    for clause in input.split(',') {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        out.push(parse_clause(clause)?);
    }
    Ok(out)
}

fn parse_clause(clause: &str) -> Result<Predicate, String> {
    let bytes = clause.as_bytes();
    let mut split = None;
    for i in 0..bytes.len() {
        let two = bytes.get(i..i + 2);
        if let Some(op) = two.and_then(|t| match t {
            b"<=" => Some(CmpOp::Le),
            b">=" => Some(CmpOp::Ge),
            b"!=" => Some(CmpOp::Ne),
            _ => None,
        }) {
            split = Some((i, 2, op));
            break;
        }
        match bytes[i] {
            b'=' => {
                split = Some((i, 1, CmpOp::Eq));
                break;
            }
            b'<' => {
                split = Some((i, 1, CmpOp::Lt));
                break;
            }
            b'>' => {
                split = Some((i, 1, CmpOp::Gt));
                break;
            }
            _ => {}
        }
    }
    let Some((at, width, op)) = split else {
        return Err(format!("clause '{clause}': no operator (=, !=, <, <=, >, >=)"));
    };
    let field = clause[..at].trim();
    let value = clause[at + width..].trim();
    if field.is_empty() {
        return Err(format!("clause '{clause}': empty field name"));
    }
    if value.is_empty() {
        return Err(format!("clause '{clause}': empty value"));
    }
    Ok(Predicate {
        field: field.to_string(),
        op,
        value: parse_value(value),
    })
}

fn parse_value(text: &str) -> PredValue {
    let quoted = (text.starts_with('"') && text.ends_with('"') && text.len() >= 2)
        || (text.starts_with('\'') && text.ends_with('\'') && text.len() >= 2);
    if quoted {
        return PredValue::Str(text[1..text.len() - 1].to_string());
    }
    match text {
        "true" => return PredValue::Bool(true),
        "false" => return PredValue::Bool(false),
        _ => {}
    }
    if let Ok(n) = text.parse::<f64>() {
        return PredValue::Num(n);
    }
    PredValue::Str(text.to_string())
}

/// Result-set shaping options.
#[derive(Debug, Clone, Default)]
pub struct QueryOptions {
    /// Restrict to the N most recently registered runs (`None` = all).
    pub last_runs: Option<usize>,
    /// Stop after this many matching rows (`None` = unbounded).
    pub limit: Option<usize>,
}

/// One matching result record, fully materialized.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryRow {
    /// Task id the result belongs to.
    pub id: String,
    /// Run label that produced it.
    pub run: String,
    /// The whole record document (`params`, `value`, `hash`, …).
    pub doc: Json,
}

impl ResultStore {
    /// Evaluates `preds` over every live result record, in log order.
    /// Non-matching records are never materialized (see module docs).
    pub fn query(&self, preds: &[Predicate], opts: &QueryOptions) -> io::Result<Vec<QueryRow>> {
        let inner = self.lock();
        let allowed: Option<HashSet<&str>> = opts
            .last_runs
            .map(|n| inner.runs.iter().rev().take(n).map(|s| s.as_str()).collect());
        let mut by_seg: BTreeMap<u64, Vec<super::index::Loc>> = BTreeMap::new();
        for (_, loc) in inner.index.entries_with_prefix("r:") {
            by_seg.entry(loc.segment).or_default().push(loc);
        }
        let limit = opts.limit.unwrap_or(usize::MAX);
        let mut rows = Vec::new();
        'segments: for (seg, mut locs) in by_seg {
            locs.sort_unstable_by_key(|l| l.offset);
            let path = segment::segment_path(&inner.dir, seg);
            let bytes = fs::read(&path)?;
            for loc in locs {
                let body = frame_body(&bytes, loc.offset, loc.body_len).ok_or_else(|| {
                    io::Error::other(format!("segment {seg:06}: bad frame at {}", loc.offset))
                })?;
                let matched = record_matches(body, preds, allowed.as_ref())
                    .map_err(|e| io::Error::other(format!("segment {seg:06}: {e}")))?;
                if !matched {
                    continue;
                }
                let doc = materialize_record(body)
                    .map_err(|e| io::Error::other(format!("segment {seg:06}: {e}")))?;
                rows.push(QueryRow {
                    id: doc.get("id").and_then(|j| j.as_str()).unwrap_or_default().to_string(),
                    run: doc.get("run").and_then(|j| j.as_str()).unwrap_or_default().to_string(),
                    doc,
                });
                if rows.len() >= limit {
                    break 'segments;
                }
            }
        }
        Ok(rows)
    }
}

/// Extracts and CRC-verifies the body slice of the frame at `offset`.
fn frame_body(bytes: &[u8], offset: u64, body_len: u32) -> Option<&[u8]> {
    let start = offset as usize;
    let header_end = start.checked_add(segment::FRAME_HEADER as usize)?;
    let end = header_end.checked_add(body_len as usize)?;
    if end > bytes.len() {
        return None;
    }
    let len = u32::from_le_bytes(bytes[start..start + 4].try_into().unwrap());
    let crc = u32::from_le_bytes(bytes[start + 4..header_end].try_into().unwrap());
    let body = &bytes[header_end..end];
    (len == body_len && crc32(body) == crc).then_some(body)
}

/// Lazy match: scalar `run` probe for the recency filter, then per-field
/// probes of the `params` subtree. Builds no tree.
fn record_matches(
    body: &[u8],
    preds: &[Predicate],
    allowed: Option<&HashSet<&str>>,
) -> Result<bool, ScanError> {
    let scanner = Scanner::new(body)?;
    if let Some(allowed) = allowed {
        let run = scanner.field("run")?;
        match run.as_ref().and_then(|v| v.as_str()) {
            Some(r) if allowed.contains(r) => {}
            _ => return Ok(false),
        }
    }
    if preds.is_empty() {
        return Ok(true);
    }
    let Some(params) = scanner.field("params")? else {
        return Ok(false);
    };
    // Records without a params object (e.g. migrated checkpoint values)
    // simply never match a parameter predicate.
    let Ok(params) = Scanner::from_raw(&params) else {
        return Ok(false);
    };
    for pred in preds {
        let v = params.field(&pred.field)?;
        if !pred.matches(v.as_ref()) {
            return Ok(false);
        }
    }
    Ok(true)
}

/// Builds the record's full [`Json`] with exactly one materialization —
/// the accounting hook the acceptance tests assert on.
fn materialize_record(body: &[u8]) -> Result<Json, ScanError> {
    let raw = if codec::is_binary(body) {
        // Past the magic byte a binary document is one complete tagged
        // value — precisely the shape `ScanValue::Raw` wants.
        ScanValue::Raw { bytes: &body[1..], binary: true }
    } else {
        ScanValue::Raw { bytes: body, binary: false }
    };
    raw.materialize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::codec::WireFormat;
    use crate::util::fs::TempDir;
    use crate::util::scan::materialized_count;

    fn preds(s: &str) -> Vec<Predicate> {
        parse_predicates(s).unwrap()
    }

    #[test]
    fn parse_clauses_and_types() {
        let ps = preds("model=svc, lr<=0.1,folds>2, note!=x");
        assert_eq!(ps.len(), 4);
        assert_eq!(ps[0], Predicate {
            field: "model".into(),
            op: CmpOp::Eq,
            value: PredValue::Str("svc".into())
        });
        assert_eq!(ps[1].op, CmpOp::Le);
        assert_eq!(ps[1].value, PredValue::Num(0.1));
        assert_eq!(ps[2].op, CmpOp::Gt);
        let ps = preds("flag=true, ver=\"3\", n>=10");
        assert_eq!(ps[0].value, PredValue::Bool(true));
        assert_eq!(ps[1].value, PredValue::Str("3".into()));
        assert_eq!(ps[2], Predicate {
            field: "n".into(),
            op: CmpOp::Ge,
            value: PredValue::Num(10.0)
        });
        assert!(parse_predicates("no-operator-here").is_err());
        assert!(parse_predicates("=5").is_err());
        assert!(parse_predicates("x=").is_err());
        assert!(parse_predicates("").unwrap().is_empty());
    }

    #[test]
    fn predicate_semantics() {
        let p = preds("lr<=0.1").remove(0);
        assert!(p.matches(Some(&ScanValue::Num(0.1))));
        assert!(p.matches(Some(&ScanValue::Num(0.05))));
        assert!(!p.matches(Some(&ScanValue::Num(0.2))));
        assert!(!p.matches(Some(&ScanValue::Str("0.05".into()))), "type mismatch");
        assert!(!p.matches(None), "missing field");
        let p = preds("model!=svc").remove(0);
        assert!(p.matches(Some(&ScanValue::Str("tree".into()))));
        assert!(!p.matches(Some(&ScanValue::Str("svc".into()))));
        assert!(!p.matches(None), "!= still requires presence");
        let p = preds("flag=true").remove(0);
        assert!(p.matches(Some(&ScanValue::Bool(true))));
        assert!(!p.matches(Some(&ScanValue::Bool(false))));
        let p = preds("flag<true").remove(0);
        assert!(!p.matches(Some(&ScanValue::Bool(false))), "bools only =/!=");
    }

    fn seed_store(td: &TempDir, wire: WireFormat) -> std::sync::Arc<ResultStore> {
        let store = ResultStore::open(td.path()).unwrap();
        store.set_auto_compact(false);
        store.set_wire(wire);
        let models = ["svc", "tree", "forest"];
        for (r, run) in ["run-a", "run-b", "run-c"].iter().enumerate() {
            store.begin_run(run).unwrap();
            for i in 0..6 {
                let id = format!("{run}-{i}");
                let params = Json::obj(vec![
                    ("model", Json::str(models[i % 3])),
                    ("lr", Json::Num(i as f64 / 100.0)),
                    ("fold", Json::int(r as i64)),
                ]);
                store.put_result(&id, &params, &Json::Num(i as f64)).unwrap();
            }
        }
        store
    }

    #[test]
    fn query_filters_runs_params_and_limits() {
        for wire in [WireFormat::Binary, WireFormat::Json] {
            let td = TempDir::new("query-basic").unwrap();
            let store = seed_store(&td, wire);
            // All records, no predicates.
            let all = store.query(&[], &QueryOptions::default()).unwrap();
            assert_eq!(all.len(), 18, "{wire:?}");
            // Parameter predicate across runs: model=svc at i∈{0,3} → 2/run.
            let svc = store.query(&preds("model=svc"), &QueryOptions::default()).unwrap();
            assert_eq!(svc.len(), 6, "{wire:?}");
            assert!(svc.iter().all(|r| {
                r.doc.get("params").and_then(|p| p.get("model")).and_then(|m| m.as_str())
                    == Some("svc")
            }));
            // Conjunction narrows: lr<=0.01 keeps i∈{0,1} → svc ∩ = i=0.
            let both =
                store.query(&preds("model=svc, lr<=0.01"), &QueryOptions::default()).unwrap();
            assert_eq!(both.len(), 3, "{wire:?}");
            // Recency: last 2 runs only.
            let recent = store
                .query(&preds("model=svc"), &QueryOptions {
                    last_runs: Some(2),
                    limit: None,
                })
                .unwrap();
            assert_eq!(recent.len(), 4, "{wire:?}");
            assert!(recent.iter().all(|r| r.run == "run-b" || r.run == "run-c"));
            // Limit caps rows.
            let limited = store
                .query(&[], &QueryOptions { last_runs: None, limit: Some(5) })
                .unwrap();
            assert_eq!(limited.len(), 5, "{wire:?}");
        }
    }

    #[test]
    fn query_ignores_dead_and_invalidated_records() {
        let td = TempDir::new("query-dead").unwrap();
        let store = seed_store(&td, WireFormat::Binary);
        // Overwrite one id and invalidate another.
        store.begin_run("run-d").unwrap();
        let params = Json::obj(vec![("model", Json::str("svc")), ("lr", Json::Num(0.5))]);
        store.put_result("run-a-0", &params, &Json::Num(99.0)).unwrap();
        store.invalidate_result("run-b-0").unwrap();
        let rows = store.query(&[], &QueryOptions::default()).unwrap();
        assert_eq!(rows.len(), 17, "18 - 1 invalidated");
        let overwritten: Vec<_> = rows.iter().filter(|r| r.id == "run-a-0").collect();
        assert_eq!(overwritten.len(), 1);
        assert_eq!(overwritten[0].run, "run-d", "latest version wins");
        assert!(!rows.iter().any(|r| r.id == "run-b-0"));
    }

    #[test]
    fn query_10k_materializes_only_matching_records() {
        // Acceptance criterion: a 10k-result store answers a parameter
        // predicate with materialized_count moving by exactly the match
        // count — non-matching records are never built into trees.
        let td = TempDir::new("query-10k").unwrap();
        let store = ResultStore::open(td.path()).unwrap();
        store.set_auto_compact(false);
        store.begin_run("bulk").unwrap();
        let models = ["svc", "tree", "forest"];
        let mut expected = 0usize;
        for i in 0..10_000usize {
            let model = models[i % 3];
            let lr = (i % 100) as f64 / 1000.0;
            if model == "svc" && lr <= 0.01 {
                expected += 1;
            }
            let params = Json::obj(vec![
                ("model", Json::str(model)),
                ("lr", Json::Num(lr)),
                ("i", Json::int(i as i64)),
            ]);
            store.put_result(&format!("task-{i:05}"), &params, &Json::int(i as i64)).unwrap();
        }
        assert!(expected > 0 && expected < 1000, "sanity: {expected}");
        let clauses = preds("model=svc, lr<=0.01");
        let before = materialized_count();
        let rows = store.query(&clauses, &QueryOptions::default()).unwrap();
        assert_eq!(rows.len(), expected);
        assert_eq!(
            materialized_count() - before,
            expected,
            "exactly one materialization per matching record, zero otherwise"
        );
        // And the misses really were scanned, not skipped via some cache:
        // a no-predicate query sees the whole store.
        assert_eq!(store.query(&[], &QueryOptions::default()).unwrap().len(), 10_000);
    }

    #[test]
    fn query_sees_compacted_and_multi_segment_stores() {
        let td = TempDir::new("query-compact").unwrap();
        let store = seed_store(&td, WireFormat::Binary);
        store.set_segment_max(256);
        store.begin_run("run-d").unwrap();
        for i in 0..10 {
            let params = Json::obj(vec![("model", Json::str("svc")), ("lr", Json::Num(0.9))]);
            store.put_result(&format!("extra-{i}"), &params, &Json::int(i)).unwrap();
        }
        assert!(store.stats().sealed_segments >= 2);
        let before = store.query(&preds("model=svc"), &QueryOptions::default()).unwrap();
        store.compact().unwrap();
        let after = store.query(&preds("model=svc"), &QueryOptions::default()).unwrap();
        assert_eq!(before.len(), after.len());
        let mut b: Vec<&str> = before.iter().map(|r| r.id.as_str()).collect();
        let mut a: Vec<&str> = after.iter().map(|r| r.id.as_str()).collect();
        b.sort_unstable();
        a.sort_unstable();
        assert_eq!(a, b);
    }
}
