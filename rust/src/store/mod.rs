//! Cross-run result database: an embedded, std-only segment-log store.
//!
//! The cache and checkpoint layers historically persisted one file per
//! task id inside per-run directories — fine at 10³ results, hopeless at
//! the 10⁷ scale the roadmap targets, and structurally unable to answer
//! any question that spans runs. This subsystem replaces that layout (for
//! callers that opt in) with a single shared database directory:
//!
//! - [`segment`] — append-only numbered segment files; every record is a
//!   length-prefixed, CRC-framed codec document, and sealed segments end
//!   in a `seal` footer and are immutable from then on.
//! - [`index`] — a 16-shard in-memory map `key → (segment, offset)`
//!   rebuilt on open by lazy-scanning record *header fields* only (no
//!   value subtree is ever materialized during rebuild), plus a
//!   content-hash table that counts cross-run dedup and the per-segment
//!   live/dead accounting that drives compaction.
//! - [`compact`] — folds sealed segments down to their live records,
//!   crash-safe via write-new-then-atomic-rename (any interleaving of
//!   old and new files replays to the same live set).
//! - [`query`] — predicate evaluation over parameter fields using the
//!   lazy [`Scanner`], so matching never materializes non-matching
//!   records.
//!
//! ## Record kinds
//!
//! | kind       | key in index     | meaning                                |
//! |------------|------------------|----------------------------------------|
//! | `result`   | `r:<task-id>`    | a cached task result (`params`,`value`)|
//! | `ck`       | `c:<run>:<id>`   | a checkpoint completion entry          |
//! | `manifest` | `m:<run>`        | a run's checkpoint manifest            |
//! | `run`      | —                | run registration (ordering for queries)|
//! | `tomb`     | —                | invalidation of an earlier key         |
//! | `seal`     | —                | segment footer; marks it immutable     |
//!
//! Records are self-contained (values are stored inline, never by
//! reference), so compaction and recovery never need to chase pointers;
//! the content-hash table exists for dedup *accounting*, while dedup
//! *behaviour* — a repeated run executing zero tasks — falls out of task
//! ids being content hashes: the second run's cache probe finds `r:<id>`
//! already present.

pub mod compact;
pub mod index;
pub mod query;
pub mod segment;

use crate::util::codec::{self, WireFormat};
use crate::util::fs as mfs;
use crate::util::json::Json;
use crate::util::scan::Scanner;
use crate::util::sha256::sha256_hex;
use index::{Loc, ShardedIndex, SHARDS};
use segment::{RecordScan, SegmentWriter};
use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex, OnceLock, Weak};

/// Default size at which the active segment is sealed and a new one
/// started (small enough that compaction has units to work with, large
/// enough that a toy grid fits in one segment).
pub const DEFAULT_SEGMENT_MAX: u64 = 8 << 20;

/// Snapshot of store health for `memento status --store` and tests.
#[derive(Debug, Clone)]
pub struct StoreStats {
    /// Total segment files on disk (sealed + active).
    pub segments: usize,
    /// Sealed (immutable) segments.
    pub sealed_segments: usize,
    /// Live keys in the index.
    pub live_records: usize,
    /// Records superseded or invalidated — reclaimable by compaction.
    pub dead_records: u64,
    /// All indexed records replayed (live + dead).
    pub total_records: u64,
    /// Puts whose value content-hash was already present (cross-run dedup).
    pub dedup_hits: u64,
    /// Distinct runs registered.
    pub runs: usize,
    /// Completed compaction passes since open.
    pub compactions: u64,
    /// Live-key occupancy of each index shard.
    pub shard_occupancy: [usize; SHARDS],
    /// Warnings accumulated at open (tail damage, undecodable records).
    pub warnings: usize,
}

/// What a [`ResultStore::migrate_dir`] pass folded into the store.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MigrationReport {
    /// Result records written (cache entries / succeeded run values).
    pub results: usize,
    /// Checkpoint completion entries written.
    pub ck_entries: usize,
    /// Run manifests written.
    pub manifests: usize,
    /// Files present but skipped (undecodable or not entry-shaped).
    pub skipped: usize,
}

pub(crate) struct Inner {
    pub(crate) dir: PathBuf,
    pub(crate) wire: WireFormat,
    pub(crate) writer: SegmentWriter,
    pub(crate) sealed: Vec<u64>,
    pub(crate) index: ShardedIndex,
    pub(crate) runs: Vec<String>,
    pub(crate) current_run: Option<String>,
    pub(crate) compactions: u64,
    pub(crate) auto_compact: bool,
    pub(crate) segment_max: u64,
    pub(crate) warnings: Vec<String>,
}

/// Handle to one store directory. Cheap to share (`Arc`); all operations
/// are internally synchronized behind one mutex — the write path is a
/// single appender by construction, and reads are index lookups plus one
/// frame read.
pub struct ResultStore {
    inner: Mutex<Inner>,
    pub(crate) compacting: AtomicBool,
    pub(crate) me: OnceLock<Weak<ResultStore>>,
}

/// Everything `scan_dir` learns from replaying the segment files.
struct ScanState {
    index: ShardedIndex,
    runs: Vec<String>,
    sealed: Vec<u64>,
    tail: Option<TailInfo>,
    warnings: Vec<String>,
}

struct TailInfo {
    id: u64,
    sealed: bool,
    valid_len: u64,
    records: u64,
}

impl ResultStore {
    /// Opens (or creates) the store at `dir`, rebuilding the index by
    /// scanning segment record headers. Damaged tails are truncated with
    /// a warning ([`ResultStore::open_warnings`]), never a panic.
    pub fn open(dir: impl Into<PathBuf>) -> io::Result<Arc<ResultStore>> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        segment::remove_temp_files(&dir)?;
        let st = scan_dir(&dir)?;
        let writer = match &st.tail {
            Some(t) if !t.sealed => SegmentWriter::open_tail(&dir, t.id, t.valid_len, t.records)?,
            Some(t) => SegmentWriter::create(&dir, t.id + 1)?,
            None => SegmentWriter::create(&dir, 1)?,
        };
        let inner = Inner {
            dir,
            wire: WireFormat::default(),
            writer,
            sealed: st.sealed,
            index: st.index,
            runs: st.runs,
            current_run: None,
            compactions: 0,
            auto_compact: true,
            segment_max: DEFAULT_SEGMENT_MAX,
            warnings: st.warnings,
        };
        let store = Arc::new(ResultStore {
            inner: Mutex::new(inner),
            compacting: AtomicBool::new(false),
            me: OnceLock::new(),
        });
        let _ = store.me.set(Arc::downgrade(&store));
        Ok(store)
    }

    /// True when `dir` already holds segment files — the layout
    /// auto-detection hook used by `ResultCache::open` and the CLI.
    pub fn is_store_dir(dir: &Path) -> bool {
        segment::list_segments(dir).map(|s| !s.is_empty()).unwrap_or(false)
    }

    /// The store directory.
    pub fn dir(&self) -> PathBuf {
        self.lock().dir.clone()
    }

    /// Sets the wire format for *future* appends (existing records keep
    /// their format; readers auto-detect per record).
    pub fn set_wire(&self, wire: WireFormat) {
        self.lock().wire = wire;
    }

    /// Enables/disables the automatic background compaction trigger
    /// (on by default; tests that inspect segment layouts turn it off).
    pub fn set_auto_compact(&self, on: bool) {
        self.lock().auto_compact = on;
    }

    /// Overrides the active-segment roll size (tests/benches use small
    /// values to force multi-segment layouts).
    pub fn set_segment_max(&self, bytes: u64) {
        self.lock().segment_max = bytes.max(1);
    }

    /// Warnings accumulated while opening (damaged tails, undecodable
    /// records). Empty for a healthy store.
    pub fn open_warnings(&self) -> Vec<String> {
        self.lock().warnings.clone()
    }

    // ---- runs ------------------------------------------------------------

    /// Registers `label` as the current run: appends a `run` record (so
    /// query recency spans process restarts) and stamps subsequent result
    /// records with the label.
    pub fn begin_run(&self, label: &str) -> io::Result<()> {
        let mut inner = self.lock();
        let doc = Json::obj(vec![("kind", Json::str("run")), ("run", Json::str(label))]);
        append_locked(&mut inner, &doc)?;
        note_run(&mut inner.runs, label);
        inner.current_run = Some(label.to_string());
        self.after_append(inner)
    }

    /// Run labels in recency order (oldest first; re-registering moves a
    /// label to the end).
    pub fn runs(&self) -> Vec<String> {
        self.lock().runs.clone()
    }

    /// The label set by the latest [`ResultStore::begin_run`], if any.
    pub fn current_run(&self) -> Option<String> {
        self.lock().current_run.clone()
    }

    // ---- results ---------------------------------------------------------

    /// Appends a task result record. Returns `true` when the value's
    /// content hash was already present in the store (a cross-run dedup
    /// hit — counted, but the record is still written so every run's
    /// provenance survives).
    pub fn put_result(&self, id: &str, params: &Json, value: &Json) -> io::Result<bool> {
        self.put_result_exp(id, params, value, None)
    }

    /// Like [`ResultStore::put_result`], additionally stamping the record
    /// with the registry entry that produced it — top-level `exp` /
    /// `exp_version` fields — so cross-run audits can attribute every
    /// result to an experiment and the version that computed it. `None`
    /// writes the byte-identical pre-registry record shape.
    pub fn put_result_exp(
        &self,
        id: &str,
        params: &Json,
        value: &Json,
        exp: Option<(&str, &str)>,
    ) -> io::Result<bool> {
        let hash = sha256_hex(value.canonical().as_bytes());
        let mut inner = self.lock();
        let run = inner.current_run.clone().unwrap_or_else(|| "adhoc".to_string());
        let mut fields = vec![
            ("kind", Json::str("result")),
            ("id", Json::str(id)),
            ("run", Json::str(run)),
            ("hash", Json::str(&hash)),
        ];
        if let Some((name, version)) = exp {
            fields.push(("exp", Json::str(name)));
            fields.push(("exp_version", Json::str(version)));
        }
        fields.push(("params", params.clone()));
        fields.push(("value", value.clone()));
        let doc = Json::obj(fields);
        let loc = append_locked(&mut inner, &doc)?;
        inner.index.record_put(format!("r:{id}"), loc);
        let dup = inner.index.note_hash(&hash);
        self.after_append(inner)?;
        Ok(dup)
    }

    /// Reads a result's `value` subtree, materializing exactly that one
    /// subtree (the same lazy-scan contract as the cache's cold `get`).
    /// `Ok(None)` for an absent or invalidated id; `Err` for a record the
    /// index points at but the segment cannot produce (corruption).
    pub fn get_result(&self, id: &str) -> io::Result<Option<Json>> {
        let inner = self.lock();
        let Some(loc) = inner.index.get(&format!("r:{id}")) else {
            return Ok(None);
        };
        let body = read_loc(&inner, loc)?;
        let value = Scanner::new(&body)
            .and_then(|s| s.field("value"))
            .map_err(|e| io::Error::other(format!("result record for {id}: {e}")))?
            .ok_or_else(|| io::Error::other(format!("result record for {id} has no value")))?;
        let json = value
            .materialize()
            .map_err(|e| io::Error::other(format!("result record for {id}: {e}")))?;
        Ok(Some(json))
    }

    /// True when a live result record exists for `id`.
    pub fn contains_result(&self, id: &str) -> bool {
        self.lock().index.get(&format!("r:{id}")).is_some()
    }

    /// Ids of every live result record (unordered). The store-backed
    /// cache seeds its memory-tier index from this at open.
    pub fn result_ids(&self) -> Vec<String> {
        self.lock()
            .index
            .entries_with_prefix("r:")
            .into_iter()
            .map(|(k, _)| k["r:".len()..].to_string())
            .collect()
    }

    /// Tombstones the result for `id`; returns whether anything was live.
    pub fn invalidate_result(&self, id: &str) -> io::Result<bool> {
        self.tombstone(&format!("r:{id}"))
    }

    /// Tombstones every live result record (the store-backed analogue of
    /// wiping a cache directory). Returns how many were invalidated.
    pub fn clear_results(&self) -> io::Result<usize> {
        let keys: Vec<String> = {
            let inner = self.lock();
            inner.index.entries_with_prefix("r:").into_iter().map(|(k, _)| k).collect()
        };
        for key in &keys {
            self.tombstone(key)?;
        }
        Ok(keys.len())
    }

    /// Tombstones the checkpoint manifest and every checkpoint entry for
    /// `run`, so a fresh checkpoint reusing the label starts clean.
    /// Result records are untouched — they belong to the cross-run cache.
    /// Returns how many records were tombstoned.
    pub fn clear_run(&self, run: &str) -> io::Result<usize> {
        let mut keys: Vec<String> = {
            let inner = self.lock();
            inner
                .index
                .entries_with_prefix(&format!("c:{run}:"))
                .into_iter()
                .map(|(k, _)| k)
                .collect()
        };
        keys.push(format!("m:{run}"));
        let mut n = 0;
        for key in &keys {
            if self.tombstone(key)? {
                n += 1;
            }
        }
        Ok(n)
    }

    fn tombstone(&self, key: &str) -> io::Result<bool> {
        let mut inner = self.lock();
        if inner.index.get(key).is_none() {
            return Ok(false);
        }
        let doc = Json::obj(vec![("kind", Json::str("tomb")), ("key", Json::str(key))]);
        let loc = append_locked(&mut inner, &doc)?;
        inner.index.record_tombstone(key.to_string(), loc);
        self.after_append(inner)?;
        Ok(true)
    }

    // ---- checkpoint backing ----------------------------------------------

    /// Writes (or supersedes) the checkpoint manifest record for `run`.
    /// `fields` carries the manifest body (fingerprint, version, totals).
    pub fn put_manifest(&self, run: &str, fields: &Json) -> io::Result<()> {
        let doc = with_header(fields, vec![("kind", Json::str("manifest")), ("run", Json::str(run))]);
        let mut inner = self.lock();
        let loc = append_locked(&mut inner, &doc)?;
        inner.index.record_put(format!("m:{run}"), loc);
        self.after_append(inner)
    }

    /// Reads the manifest record for `run`, fully materialized.
    pub fn get_manifest(&self, run: &str) -> io::Result<Option<Json>> {
        let inner = self.lock();
        let Some(loc) = inner.index.get(&format!("m:{run}")) else {
            return Ok(None);
        };
        let body = read_loc(&inner, loc)?;
        codec::read_document(&body)
            .map(Some)
            .map_err(|e| io::Error::other(format!("manifest record for {run}: {e}")))
    }

    /// Appends a checkpoint completion entry for (`run`, `id`). `fields`
    /// carries the entry body (value/failure, duration, attempts).
    pub fn put_ck_entry(&self, run: &str, id: &str, fields: &Json) -> io::Result<()> {
        let doc = with_header(
            fields,
            vec![
                ("kind", Json::str("ck")),
                ("id", Json::str(id)),
                ("run", Json::str(run)),
            ],
        );
        let mut inner = self.lock();
        let loc = append_locked(&mut inner, &doc)?;
        inner.index.record_put(format!("c:{run}:{id}"), loc);
        self.after_append(inner)
    }

    /// All live checkpoint entries for `run`, fully materialized (resume
    /// needs every field anyway).
    pub fn ck_entries(&self, run: &str) -> io::Result<Vec<Json>> {
        let inner = self.lock();
        let mut entries = inner.index.entries_with_prefix(&format!("c:{run}:"));
        entries.sort_by_key(|(_, loc)| (loc.segment, loc.offset));
        let mut out = Vec::with_capacity(entries.len());
        for (key, loc) in entries {
            let body = read_loc(&inner, loc)?;
            let doc = codec::read_document(&body)
                .map_err(|e| io::Error::other(format!("ck record {key}: {e}")))?;
            out.push(doc);
        }
        Ok(out)
    }

    // ---- maintenance -----------------------------------------------------

    /// Fsyncs the active segment (appends are not individually synced).
    pub fn sync(&self) -> io::Result<()> {
        self.lock().writer.sync()
    }

    /// Seals the active segment (footer + fsync) and starts a new one.
    pub fn seal_active(&self) -> io::Result<()> {
        let mut inner = self.lock();
        roll_locked(&mut inner)
    }

    /// Current health snapshot.
    pub fn stats(&self) -> StoreStats {
        let inner = self.lock();
        StoreStats {
            segments: inner.sealed.len() + 1,
            sealed_segments: inner.sealed.len(),
            live_records: inner.index.len(),
            dead_records: inner.index.dead_records(),
            total_records: inner.index.total_records(),
            dedup_hits: inner.index.dedup_hits(),
            runs: inner.runs.len(),
            compactions: inner.compactions,
            shard_occupancy: inner.index.shard_occupancy(),
            warnings: inner.warnings.len(),
        }
    }

    // ---- migration -------------------------------------------------------

    /// Folds a legacy per-run directory into the store. Auto-detects the
    /// layout: a directory with a `manifest.json` is a checkpoint run dir
    /// (manifest + completion entries are migrated, and succeeded values
    /// additionally become result records); anything else is treated as a
    /// cache directory of `<id>.json` entry files. The legacy directory
    /// is never modified.
    pub fn migrate_dir(&self, legacy: &Path) -> io::Result<MigrationReport> {
        let label = legacy
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("legacy")
            .to_string();
        if legacy.join("manifest.json").exists() {
            self.migrate_run_dir(legacy, &label)
        } else {
            self.begin_run(&format!("migrate:{label}"))?;
            self.migrate_cache_dir(legacy)
        }
    }

    fn migrate_cache_dir(&self, dir: &Path) -> io::Result<MigrationReport> {
        let mut report = MigrationReport::default();
        for path in mfs::list_files_with_ext(dir, "json")? {
            let bytes = fs::read(&path)?;
            let Ok(doc) = codec::read_document(&bytes) else {
                report.skipped += 1;
                continue;
            };
            let (Some(id), Some(value)) = (doc.get("id").and_then(|j| j.as_str()), doc.get("value"))
            else {
                report.skipped += 1;
                continue;
            };
            let params = doc.get("params").cloned().unwrap_or(Json::Null);
            // Cache entries written by a registry-aware Dir backing stamp
            // the experiment that produced them; carry that through.
            let exp = match (
                doc.get("exp").and_then(|j| j.as_str()),
                doc.get("exp_version").and_then(|j| j.as_str()),
            ) {
                (Some(n), Some(v)) => Some((n, v)),
                _ => None,
            };
            self.put_result_exp(id, &params, value, exp)?;
            report.results += 1;
        }
        self.sync()?;
        Ok(report)
    }

    fn migrate_run_dir(&self, dir: &Path, run: &str) -> io::Result<MigrationReport> {
        let mut report = MigrationReport::default();
        self.begin_run(run)?;
        let bytes = fs::read(dir.join("manifest.json"))?;
        let manifest = codec::read_document(&bytes)
            .map_err(|e| io::Error::other(format!("manifest in {}: {e}", dir.display())))?;
        let header = Json::obj(vec![
            (
                "matrix_fingerprint",
                manifest.get("matrix_fingerprint").cloned().unwrap_or(Json::Null),
            ),
            ("version", manifest.get("version").cloned().unwrap_or(Json::Null)),
            ("total_tasks", manifest.get("total_tasks").cloned().unwrap_or(Json::Null)),
        ]);
        self.put_manifest(run, &header)?;
        report.manifests += 1;
        if let Some(completed) = manifest.get("completed").and_then(|c| c.as_obj()) {
            for (id, entry) in completed {
                self.put_ck_entry(run, id, entry)?;
                report.ck_entries += 1;
                let failed = entry.get("failed").is_some_and(|f| !f.is_null());
                if !failed {
                    if let Some(value) = entry.get("value").filter(|v| !v.is_null()) {
                        self.put_result(id, &Json::Null, value)?;
                        report.results += 1;
                    }
                }
            }
        }
        self.sync()?;
        Ok(report)
    }

    // ---- internals shared with compact/query -----------------------------

    pub(crate) fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Post-append bookkeeping: roll the active segment when it crossed
    /// the size threshold, then (maybe) kick background compaction. Takes
    /// the guard by value so the compaction spawn happens after unlock.
    fn after_append(&self, mut inner: std::sync::MutexGuard<'_, Inner>) -> io::Result<()> {
        let mut rolled = false;
        if inner.writer.offset() >= inner.segment_max {
            roll_locked(&mut inner)?;
            rolled = true;
        }
        let trigger = rolled && inner.auto_compact && compact::should_compact(&inner);
        drop(inner);
        if trigger {
            if let Some(me) = self.me.get().and_then(|w| w.upgrade()) {
                me.compact_in_background();
            }
        }
        Ok(())
    }
}

/// Appends `doc` to the active segment; returns its location.
fn append_locked(inner: &mut Inner, doc: &Json) -> io::Result<Loc> {
    let body = codec::write_document(doc, inner.wire);
    let offset = inner.writer.append(&body)?;
    Ok(Loc {
        segment: inner.writer.id(),
        offset,
        body_len: body.len() as u32,
    })
}

/// Seals the active segment and starts the next one.
fn roll_locked(inner: &mut Inner) -> io::Result<()> {
    let seal = Json::obj(vec![
        ("kind", Json::str("seal")),
        ("records", Json::int(inner.writer.records() as i64 + 1)),
    ]);
    let body = codec::write_document(&seal, inner.wire);
    let next = SegmentWriter::create(&inner.dir, inner.writer.id() + 1)?;
    let old = std::mem::replace(&mut inner.writer, next);
    let old_id = old.id();
    old.seal(&body)?;
    mfs::sync_dir(&inner.dir)?;
    inner.sealed.push(old_id);
    Ok(())
}

/// Reads and CRC-verifies the record at `loc`.
pub(crate) fn read_loc(inner: &Inner, loc: Loc) -> io::Result<Vec<u8>> {
    segment::read_record(&segment::segment_path(&inner.dir, loc.segment), loc.offset, loc.body_len)
}

/// Merges record header pairs over a caller-supplied body object.
fn with_header(fields: &Json, header: Vec<(&str, Json)>) -> Json {
    let mut obj = match fields {
        Json::Obj(map) => map.clone(),
        _ => Default::default(),
    };
    for (k, v) in header {
        obj.insert(k.to_string(), v);
    }
    Json::Obj(obj)
}

/// Appends `label` to the run list, moving it to the end if present.
fn note_run(runs: &mut Vec<String>, label: &str) {
    runs.retain(|r| r != label);
    runs.push(label.to_string());
}

/// Builds the per-tenant run label the daemon registers submissions
/// under: `tenant/run_id`. Run labels are opaque strings to the store —
/// the `/` is a display convention, not a path — and [`split_tenant`]
/// is its inverse for status views and per-tenant queries.
pub fn tenant_label(tenant: &str, run_id: &str) -> String {
    format!("{tenant}/{run_id}")
}

/// Splits a [`tenant_label`]-shaped run label back into
/// `(tenant, run_id)`. Labels without a `/` (every pre-daemon run)
/// come back with an empty tenant.
pub fn split_tenant(label: &str) -> (&str, &str) {
    match label.split_once('/') {
        Some((tenant, run_id)) => (tenant, run_id),
        None => ("", label),
    }
}

/// Replays every segment's record headers into a fresh [`ScanState`].
/// Only scalar header fields are scanned — `params`/`value` subtrees are
/// skipped byte-wise, which is what keeps open cost proportional to
/// record count, not payload size.
fn scan_dir(dir: &Path) -> io::Result<ScanState> {
    let segs = segment::list_segments(dir)?;
    let mut st = ScanState {
        index: ShardedIndex::new(),
        runs: Vec::new(),
        sealed: Vec::new(),
        tail: None,
        warnings: Vec::new(),
    };
    let last = segs.len().saturating_sub(1);
    for (i, (id, path)) in segs.iter().enumerate() {
        let bytes = fs::read(path)?;
        let mut scan = RecordScan::new(&bytes);
        let mut records = 0u64;
        let mut last_was_seal = false;
        for (offset, body) in scan.by_ref() {
            records += 1;
            last_was_seal = apply_record(&mut st, *id, offset, body);
        }
        if let Some(d) = scan.damage() {
            st.warnings.push(format!(
                "segment {id:06}: {} at byte {} — kept {} valid records, tail dropped",
                d.reason, d.at, records
            ));
        }
        if last_was_seal {
            st.sealed.push(*id);
        } else if i != last {
            // Protocol never leaves an unsealed non-tail segment behind,
            // but tolerate one (e.g. hand-copied files): it is immutable
            // from our point of view, so treat it as sealed.
            st.warnings.push(format!("segment {id:06}: missing seal footer — treated as sealed"));
            st.sealed.push(*id);
        }
        if i == last {
            st.tail = Some(TailInfo {
                id: *id,
                sealed: last_was_seal,
                valid_len: scan.valid_len(),
                records,
            });
        }
    }
    Ok(st)
}

/// Applies one record's header fields to the scan state. Returns whether
/// the record was a `seal` footer. Undecodable bodies (valid CRC, bad
/// codec bytes — possible only through external tampering) produce a
/// warning and are skipped.
fn apply_record(st: &mut ScanState, seg: u64, offset: u64, body: &[u8]) -> bool {
    let loc = Loc { segment: seg, offset, body_len: body.len() as u32 };
    let scanned = Scanner::new(body).and_then(|s| s.fields(["kind", "id", "run", "hash", "key"]));
    let [kind, id, run, hash, key] = match scanned {
        Ok(fields) => fields,
        Err(e) => {
            st.warnings.push(format!("segment {seg:06} offset {offset}: undecodable record: {e}"));
            return false;
        }
    };
    let kind = kind.as_ref().and_then(|v| v.as_str()).unwrap_or("");
    match kind {
        "result" => {
            if let Some(id) = id.as_ref().and_then(|v| v.as_str()) {
                st.index.record_put(format!("r:{id}"), loc);
                if let Some(h) = hash.as_ref().and_then(|v| v.as_str()) {
                    st.index.note_hash(h);
                }
            }
        }
        "ck" => {
            if let (Some(run), Some(id)) = (
                run.as_ref().and_then(|v| v.as_str()),
                id.as_ref().and_then(|v| v.as_str()),
            ) {
                st.index.record_put(format!("c:{run}:{id}"), loc);
            }
        }
        "manifest" => {
            if let Some(run) = run.as_ref().and_then(|v| v.as_str()) {
                st.index.record_put(format!("m:{run}"), loc);
            }
        }
        "run" => {
            if let Some(run) = run.as_ref().and_then(|v| v.as_str()) {
                note_run(&mut st.runs, run);
            }
        }
        "tomb" => {
            if let Some(key) = key.as_ref().and_then(|v| v.as_str()) {
                st.index.record_tombstone(key.to_string(), loc);
            }
        }
        "seal" => return true,
        _ => {
            st.warnings.push(format!("segment {seg:06} offset {offset}: unknown record kind"));
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;
    use crate::util::scan::materialized_count;
    use std::io::Write as _;

    fn params(model: &str, lr: f64) -> Json {
        Json::obj(vec![("model", Json::str(model)), ("lr", Json::Num(lr))])
    }

    #[test]
    fn tenant_labels_round_trip() {
        assert_eq!(tenant_label("alice", "run-7"), "alice/run-7");
        assert_eq!(split_tenant("alice/run-7"), ("alice", "run-7"));
        // Pre-daemon labels have no tenant component.
        assert_eq!(split_tenant("demo"), ("", "demo"));
        // A run id containing '/' splits at the first separator only.
        assert_eq!(split_tenant("a/b/c"), ("a", "b/c"));
    }

    fn value(score: f64) -> Json {
        Json::obj(vec![("score", Json::Num(score))])
    }

    #[test]
    fn put_get_roundtrip_and_persistence() {
        let td = TempDir::new("store-rt").unwrap();
        {
            let store = ResultStore::open(td.path()).unwrap();
            store.begin_run("run-a").unwrap();
            assert!(!store.put_result("id1", &params("svc", 0.1), &value(0.9)).unwrap());
            assert!(!store.put_result("id2", &params("tree", 0.2), &value(0.8)).unwrap());
            assert_eq!(store.get_result("id1").unwrap(), Some(value(0.9)));
            assert!(store.contains_result("id2"));
            assert!(!store.contains_result("id3"));
            store.sync().unwrap();
        }
        // Reopen: index rebuilt from disk.
        let store = ResultStore::open(td.path()).unwrap();
        assert!(store.open_warnings().is_empty());
        assert_eq!(store.get_result("id2").unwrap(), Some(value(0.8)));
        assert_eq!(store.runs(), vec!["run-a".to_string()]);
        let stats = store.stats();
        assert_eq!(stats.live_records, 2);
        assert_eq!(stats.dead_records, 0);
    }

    #[test]
    fn put_result_exp_stamps_experiment_fields() {
        use crate::store::query::QueryOptions;
        let td = TempDir::new("store-exp").unwrap();
        let store = ResultStore::open(td.path()).unwrap();
        store.begin_run("run-x").unwrap();
        store
            .put_result_exp("named", &params("svc", 0.1), &value(0.9), Some(("echo", "v1")))
            .unwrap();
        store.put_result("plain", &params("svc", 0.2), &value(0.8)).unwrap();

        let rows = store.query(&[], &QueryOptions::default()).unwrap();
        let doc_of = |id: &str| rows.iter().find(|r| r.id == id).unwrap().doc.clone();
        let named = doc_of("named");
        assert_eq!(named.get("exp").and_then(|j| j.as_str()), Some("echo"));
        assert_eq!(named.get("exp_version").and_then(|j| j.as_str()), Some("v1"));
        let plain = doc_of("plain");
        assert!(plain.get("exp").is_none(), "unnamed results keep the pre-registry shape");
        assert!(plain.get("exp_version").is_none());
        // The extra fields change nothing about retrieval or the index.
        assert_eq!(store.get_result("named").unwrap(), Some(value(0.9)));
        store.sync().unwrap();
        drop(store);
        let reopened = ResultStore::open(td.path()).unwrap();
        assert!(reopened.open_warnings().is_empty());
        assert_eq!(reopened.get_result("named").unwrap(), Some(value(0.9)));
    }

    #[test]
    fn get_materializes_only_the_value_subtree() {
        let td = TempDir::new("store-lazy").unwrap();
        let store = ResultStore::open(td.path()).unwrap();
        store.put_result("idx", &params("svc", 0.1), &value(0.5)).unwrap();
        let before = materialized_count();
        assert_eq!(store.get_result("idx").unwrap(), Some(value(0.5)));
        assert_eq!(materialized_count(), before + 1, "cold get must materialize exactly once");
    }

    #[test]
    fn overwrite_and_invalidate_track_dead_records() {
        let td = TempDir::new("store-dead").unwrap();
        let store = ResultStore::open(td.path()).unwrap();
        store.put_result("a", &params("svc", 0.1), &value(1.0)).unwrap();
        store.put_result("a", &params("svc", 0.1), &value(2.0)).unwrap();
        assert_eq!(store.get_result("a").unwrap(), Some(value(2.0)));
        assert!(store.invalidate_result("a").unwrap());
        assert!(!store.invalidate_result("a").unwrap());
        assert_eq!(store.get_result("a").unwrap(), None);
        let stats = store.stats();
        assert_eq!(stats.live_records, 0);
        assert_eq!(stats.dead_records, 2);
    }

    #[test]
    fn tombstones_survive_reopen() {
        let td = TempDir::new("store-tomb").unwrap();
        {
            let store = ResultStore::open(td.path()).unwrap();
            store.put_result("gone", &Json::Null, &value(1.0)).unwrap();
            store.invalidate_result("gone").unwrap();
            store.sync().unwrap();
        }
        let store = ResultStore::open(td.path()).unwrap();
        assert_eq!(store.get_result("gone").unwrap(), None);
    }

    #[test]
    fn dedup_hits_count_identical_values_across_runs() {
        let td = TempDir::new("store-dedup").unwrap();
        let store = ResultStore::open(td.path()).unwrap();
        store.begin_run("one").unwrap();
        assert!(!store.put_result("x1", &params("svc", 0.1), &value(0.7)).unwrap());
        store.begin_run("two").unwrap();
        assert!(store.put_result("x2", &params("svc", 0.2), &value(0.7)).unwrap());
        assert_eq!(store.stats().dedup_hits, 1);
        assert_eq!(store.runs(), vec!["one".to_string(), "two".to_string()]);
    }

    #[test]
    fn segment_roll_and_seal() {
        let td = TempDir::new("store-roll").unwrap();
        let store = ResultStore::open(td.path()).unwrap();
        store.set_auto_compact(false);
        store.set_segment_max(256);
        for i in 0..20 {
            store.put_result(&format!("id{i}"), &params("svc", 0.1), &value(i as f64)).unwrap();
        }
        let stats = store.stats();
        assert!(stats.sealed_segments >= 2, "{stats:?}");
        // All records still reachable across segments.
        for i in 0..20 {
            assert_eq!(store.get_result(&format!("id{i}")).unwrap(), Some(value(i as f64)));
        }
        // Reopen sees the same layout.
        drop(store);
        let store = ResultStore::open(td.path()).unwrap();
        assert!(store.open_warnings().is_empty());
        assert_eq!(store.stats().sealed_segments, stats.sealed_segments);
        assert_eq!(store.get_result("id7").unwrap(), Some(value(7.0)));
    }

    #[test]
    fn corrupt_tail_is_skipped_with_warning_and_store_stays_writable() {
        let td = TempDir::new("store-corrupt").unwrap();
        {
            let store = ResultStore::open(td.path()).unwrap();
            store.put_result("keep", &Json::Null, &value(1.0)).unwrap();
            store.put_result("torn", &Json::Null, &value(2.0)).unwrap();
            store.sync().unwrap();
        }
        // Flip a byte in the last record's body: CRC must reject it.
        let seg = segment::segment_path(td.path(), 1);
        let mut bytes = fs::read(&seg).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        fs::write(&seg, &bytes).unwrap();

        let store = ResultStore::open(td.path()).unwrap();
        let warnings = store.open_warnings();
        assert_eq!(warnings.len(), 1, "{warnings:?}");
        assert!(warnings[0].contains("crc mismatch"), "{warnings:?}");
        assert_eq!(store.get_result("keep").unwrap(), Some(value(1.0)));
        assert_eq!(store.get_result("torn").unwrap(), None);
        // The damaged tail was truncated: appends continue cleanly.
        store.put_result("after", &Json::Null, &value(3.0)).unwrap();
        store.sync().unwrap();
        drop(store);
        let store = ResultStore::open(td.path()).unwrap();
        assert!(store.open_warnings().is_empty());
        assert_eq!(store.get_result("after").unwrap(), Some(value(3.0)));
    }

    #[test]
    fn truncated_tail_is_skipped_with_warning() {
        let td = TempDir::new("store-trunc").unwrap();
        {
            let store = ResultStore::open(td.path()).unwrap();
            store.put_result("keep", &Json::Null, &value(1.0)).unwrap();
            store.sync().unwrap();
        }
        // Simulate a torn append: half a frame header at the tail.
        let seg = segment::segment_path(td.path(), 1);
        let mut f = fs::OpenOptions::new().append(true).open(&seg).unwrap();
        f.write_all(&[42, 0, 0]).unwrap();
        drop(f);
        let store = ResultStore::open(td.path()).unwrap();
        assert_eq!(store.open_warnings().len(), 1);
        assert_eq!(store.get_result("keep").unwrap(), Some(value(1.0)));
    }

    #[test]
    fn manifest_and_ck_entries_roundtrip() {
        let td = TempDir::new("store-ck").unwrap();
        let store = ResultStore::open(td.path()).unwrap();
        let manifest = Json::obj(vec![
            ("matrix_fingerprint", Json::str("fp")),
            ("version", Json::str("v1")),
            ("total_tasks", Json::int(2)),
        ]);
        store.put_manifest("run-z", &manifest).unwrap();
        let entry = Json::obj(vec![
            ("value", value(0.5)),
            ("duration_secs", Json::Num(0.1)),
            ("attempts", Json::int(1)),
        ]);
        store.put_ck_entry("run-z", "id1", &entry).unwrap();
        store.put_ck_entry("run-z", "id2", &entry).unwrap();
        store.sync().unwrap();

        let store = ResultStore::open(td.path()).unwrap();
        let m = store.get_manifest("run-z").unwrap().unwrap();
        assert_eq!(m.get("matrix_fingerprint").and_then(|j| j.as_str()), Some("fp"));
        assert_eq!(m.get("total_tasks").and_then(|j| j.as_i64()), Some(2));
        let entries = store.ck_entries("run-z").unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].get("id").and_then(|j| j.as_str()), Some("id1"));
        assert_eq!(entries[0].get("value"), Some(&value(0.5)));
        assert!(store.ck_entries("run-other").unwrap().is_empty());
        // Manifest supersedes in place.
        store.put_manifest("run-z", &Json::obj(vec![("total_tasks", Json::int(9))])).unwrap();
        let m = store.get_manifest("run-z").unwrap().unwrap();
        assert_eq!(m.get("total_tasks").and_then(|j| j.as_i64()), Some(9));
    }

    #[test]
    fn json_wire_interoperates() {
        let td = TempDir::new("store-json").unwrap();
        let store = ResultStore::open(td.path()).unwrap();
        store.set_wire(WireFormat::Json);
        store.put_result("j1", &params("svc", 0.1), &value(0.4)).unwrap();
        store.set_wire(WireFormat::Binary);
        store.put_result("b1", &params("svc", 0.2), &value(0.6)).unwrap();
        store.sync().unwrap();
        let store = ResultStore::open(td.path()).unwrap();
        assert!(store.open_warnings().is_empty());
        assert_eq!(store.get_result("j1").unwrap(), Some(value(0.4)));
        assert_eq!(store.get_result("b1").unwrap(), Some(value(0.6)));
    }

    #[test]
    fn is_store_dir_detection() {
        let td = TempDir::new("store-detect").unwrap();
        assert!(!ResultStore::is_store_dir(td.path()));
        let store = ResultStore::open(td.path()).unwrap();
        store.put_result("x", &Json::Null, &value(1.0)).unwrap();
        drop(store);
        assert!(ResultStore::is_store_dir(td.path()));
    }
}
