//! Micro/mezzo benchmark harness (offline `criterion` replacement).
//!
//! Every `benches/*.rs` target is a `harness = false` binary built on this
//! module. Each benchmark: optional setup, warmup iterations, timed
//! iterations with per-iteration wall clock, then summary statistics
//! (mean / p50 / p95 / min / stddev) rendered as an aligned table. Output is
//! intentionally plain text so `cargo bench | tee bench_output.txt`
//! reproduces the EXPERIMENTS.md tables verbatim.

use crate::util::json::Json;
use crate::util::time::fmt_secs;
use std::path::Path;
use std::time::Instant;

/// Statistics over per-iteration timings (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    /// Timed iterations.
    pub iters: usize,
    /// Mean iteration time.
    pub mean: f64,
    /// Median iteration time.
    pub p50: f64,
    /// 95th-percentile iteration time.
    pub p95: f64,
    /// Fastest iteration.
    pub min: f64,
    /// Slowest iteration.
    pub max: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Sum of all iteration times.
    pub total: f64,
}

impl Stats {
    /// Computes the summary statistics of per-iteration timings.
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let total: f64 = samples.iter().sum();
        let mean = total / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| -> f64 {
            let idx = ((n as f64 - 1.0) * p).round() as usize;
            samples[idx.min(n - 1)]
        };
        Stats {
            iters: n,
            mean,
            p50: pct(0.50),
            p95: pct(0.95),
            min: samples[0],
            max: samples[n - 1],
            stddev: var.sqrt(),
            total,
        }
    }

    /// Iterations per second implied by the mean.
    pub fn throughput(&self) -> f64 {
        if self.mean > 0.0 {
            1.0 / self.mean
        } else {
            f64::INFINITY
        }
    }
}

/// One row of a benchmark report.
#[derive(Debug, Clone)]
pub struct Row {
    /// Benchmark name shown in the table.
    pub name: String,
    /// Timing statistics for the row.
    pub stats: Stats,
    /// Optional free-form extra column (e.g. "hit-rate 100%", "speedup 3.8x").
    pub note: String,
}

/// A named group of benchmark rows with table rendering.
pub struct Suite {
    title: String,
    rows: Vec<Row>,
}

impl Suite {
    /// Starts a named suite (prints its header immediately).
    pub fn new(title: impl Into<String>) -> Suite {
        let title = title.into();
        println!("\n=== bench suite: {title} ===");
        Suite { title, rows: Vec::new() }
    }

    /// Runs a benchmark: `warmup` untimed iterations then `iters` timed ones.
    /// The closure receives the iteration index.
    pub fn bench(&mut self, name: impl Into<String>, warmup: usize, iters: usize, mut f: impl FnMut(usize)) -> &Stats {
        let name = name.into();
        for i in 0..warmup {
            f(i);
        }
        let mut samples = Vec::with_capacity(iters);
        for i in 0..iters {
            let t = Instant::now();
            f(i);
            samples.push(t.elapsed().as_secs_f64());
        }
        let stats = Stats::from_samples(samples);
        println!(
            "  {name:<40} mean {:>9}  p50 {:>9}  p95 {:>9}  ({} iters)",
            fmt_secs(stats.mean),
            fmt_secs(stats.p50),
            fmt_secs(stats.p95),
            stats.iters
        );
        self.rows.push(Row { name, stats, note: String::new() });
        &self.rows.last().unwrap().stats
    }

    /// Like [`Suite::bench`] but with fresh per-iteration state built by
    /// `setup` outside the timed region.
    pub fn bench_with_setup<S>(
        &mut self,
        name: impl Into<String>,
        warmup: usize,
        iters: usize,
        mut setup: impl FnMut() -> S,
        mut f: impl FnMut(S),
    ) -> &Stats {
        let name = name.into();
        for _ in 0..warmup {
            f(setup());
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let state = setup();
            let t = Instant::now();
            f(state);
            samples.push(t.elapsed().as_secs_f64());
        }
        let stats = Stats::from_samples(samples);
        println!(
            "  {name:<40} mean {:>9}  p50 {:>9}  p95 {:>9}  ({} iters)",
            fmt_secs(stats.mean),
            fmt_secs(stats.p50),
            fmt_secs(stats.p95),
            stats.iters
        );
        self.rows.push(Row { name, stats, note: String::new() });
        &self.rows.last().unwrap().stats
    }

    /// Attaches a note to the most recent row.
    pub fn note(&mut self, note: impl Into<String>) {
        if let Some(r) = self.rows.last_mut() {
            r.note = note.into();
        }
    }

    /// Records an externally measured sample set as a row (for end-to-end
    /// numbers computed by the bench body itself).
    pub fn record(&mut self, name: impl Into<String>, samples: Vec<f64>, note: impl Into<String>) {
        let stats = Stats::from_samples(samples);
        self.rows.push(Row { name: name.into(), stats, note: note.into() });
    }

    /// Renders the final aligned table. Call once at the end of the target.
    pub fn finish(&self) {
        println!("\n--- {} ---", self.title);
        println!(
            "{:<42} {:>10} {:>10} {:>10} {:>10} {:>12}  {}",
            "benchmark", "mean", "p50", "p95", "min", "iters/s", "note"
        );
        for r in &self.rows {
            println!(
                "{:<42} {:>10} {:>10} {:>10} {:>10} {:>12.1}  {}",
                truncate(&r.name, 42),
                fmt_secs(r.stats.mean),
                fmt_secs(r.stats.p50),
                fmt_secs(r.stats.p95),
                fmt_secs(r.stats.min),
                r.stats.throughput(),
                r.note
            );
        }
        println!();
    }

    /// The rows benchmarked so far.
    pub fn rows(&self) -> &[Row] {
        &self.rows
    }

    /// Appends this suite's results (plus free-form derived `extras`) to a
    /// JSON trajectory file, creating it if absent. The file accumulates
    /// one entry per bench invocation so perf history is diffable across
    /// PRs (`BENCH_sched_cache.json` at the repo root is the first such
    /// trajectory). Unreadable/corrupt files are replaced with a fresh
    /// skeleton rather than erroring — a bench must never fail on
    /// bookkeeping.
    pub fn write_trajectory(&self, path: &Path, extras: Vec<(String, Json)>) {
        let mut doc = std::fs::read_to_string(path)
            .ok()
            .and_then(|t| crate::util::json::parse(&t).ok())
            .filter(|j| j.get("runs").and_then(|r| r.as_arr()).is_some())
            .unwrap_or_else(|| {
                Json::obj(vec![
                    ("schema", Json::str("memento-bench-trajectory/v1")),
                    ("runs", Json::Arr(Vec::new())),
                ])
            });

        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::str(r.name.clone())),
                    ("mean_s", Json::Num(r.stats.mean)),
                    ("p50_s", Json::Num(r.stats.p50)),
                    ("p95_s", Json::Num(r.stats.p95)),
                    ("min_s", Json::Num(r.stats.min)),
                    ("iters", Json::int(r.stats.iters as i64)),
                    ("note", Json::str(r.note.clone())),
                ])
            })
            .collect();
        let unix_secs = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let entry = Json::obj(vec![
            ("suite", Json::str(self.title.clone())),
            ("unix_secs", Json::int(unix_secs as i64)),
            ("rows", Json::Arr(rows)),
            (
                "extras",
                Json::Obj(extras.into_iter().collect()),
            ),
        ]);
        if let Json::Obj(map) = &mut doc {
            if let Some(Json::Arr(runs)) = map.get_mut("runs") {
                runs.push(entry);
            }
        }
        if let Err(e) = crate::util::fs::atomic_write(path, doc.pretty().as_bytes()) {
            eprintln!("bench: could not write trajectory {}: {e}", path.display());
        } else {
            println!("bench: trajectory appended to {}", path.display());
        }
    }
}

/// Resolves the shared scheduler/cache bench trajectory file: the
/// `MEMENTO_BENCH_OUT` env var, or `../BENCH_sched_cache.json` (benches run
/// with the package root `rust/` as cwd, the file lives at the repo root).
pub fn sched_cache_trajectory_path() -> std::path::PathBuf {
    std::env::var("MEMENTO_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("../BENCH_sched_cache.json"))
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n.saturating_sub(1)])
    }
}

/// Prevents the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.iters, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.total - 15.0).abs() < 1e-12);
        assert!((s.stddev - 2.0f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn percentiles_on_unsorted_input() {
        let s = Stats::from_samples(vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p95, 5.0);
    }

    #[test]
    fn suite_runs_and_counts() {
        let mut suite = Suite::new("unit");
        let mut count = 0usize;
        {
            let counter = &mut count;
            suite.bench("inc", 2, 10, |_| {
                *counter += 1;
            });
        }
        assert_eq!(count, 12); // 2 warmup + 10 timed
        assert_eq!(suite.rows().len(), 1);
        suite.finish();
    }

    #[test]
    fn bench_with_setup_not_timed() {
        let mut suite = Suite::new("setup");
        let stats = suite
            .bench_with_setup(
                "noop-after-sleepy-setup",
                0,
                3,
                || std::thread::sleep(std::time::Duration::from_millis(3)),
                |_| {},
            )
            .clone();
        // Setup sleep must not be in the timed region.
        assert!(stats.mean < 0.002, "mean={}", stats.mean);
    }

    #[test]
    fn throughput_sane() {
        let s = Stats::from_samples(vec![0.001; 10]);
        assert!((s.throughput() - 1000.0).abs() < 1.0);
    }

    #[test]
    fn trajectory_appends_and_survives_corruption() {
        let td = crate::util::fs::TempDir::new("bench-traj").unwrap();
        let path = td.join("traj.json");
        let mut suite = Suite::new("traj-test");
        suite.bench("noop", 0, 3, |_| {});
        suite.write_trajectory(&path, vec![("k".to_string(), Json::int(7))]);
        suite.write_trajectory(&path, Vec::new());
        let doc = crate::util::json::parse(
            &std::fs::read_to_string(&path).unwrap(),
        )
        .unwrap();
        let runs = doc.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(
            runs[0].get("extras").unwrap().get("k").unwrap().as_i64(),
            Some(7)
        );
        assert_eq!(runs[0].get("rows").unwrap().as_arr().unwrap().len(), 1);
        // Corrupt file → fresh skeleton, no panic.
        crate::util::fs::atomic_write(&path, b"not json").unwrap();
        suite.write_trajectory(&path, Vec::new());
        let doc = crate::util::json::parse(
            &std::fs::read_to_string(&path).unwrap(),
        )
        .unwrap();
        assert_eq!(doc.get("runs").unwrap().as_arr().unwrap().len(), 1);
    }
}
