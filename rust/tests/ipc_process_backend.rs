//! Integration tests for the process-isolated execution backend
//! ([`memento::ipc`]): worker processes over the std-only IPC protocol,
//! crash-requeue, and parity with the thread backend.
//!
//! # How workers spawn under libtest
//!
//! The supervisor re-executes the current binary — here, this very test
//! binary — with the worker environment set and an argv we choose:
//! `--exact ipc_worker_entry`. That runs exactly one "test",
//! [`ipc_worker_entry`], which is a no-op in a normal `cargo test` pass
//! (no worker environment) and otherwise serves task attempts over the
//! socket with this file's experiment function. This is the documented
//! pattern for using `ExecBackend::Processes` from a test binary.

#![cfg(unix)]

use memento::coordinator::journal::{Event, Journal};
use memento::prelude::*;
use memento::util::fs::TempDir;
use std::sync::Arc;
use std::time::Duration;

/// The experiment function shared by the supervisor-side tests and the
/// worker entry. Behaviour is keyed by the run's `mode` setting so one
/// entry point serves every test.
fn exp(ctx: &TaskContext) -> Result<Json, MementoError> {
    let i = ctx.param_i64("i")?;
    match ctx.setting("mode").and_then(|j| j.as_str()).unwrap_or("ok") {
        // A worker crash, not a contained failure: the process dies
        // instantly with no unwinding — from the supervisor's point of
        // view this is indistinguishable from a segfault or `kill -9`.
        "crash3" if i == 3 && ctx.attempt == 1 => std::process::abort(),
        "fail2" if i == 2 => Err(MementoError::experiment("i=2 always fails")),
        // A stuck task (heartbeats keep flowing — the worker is healthy,
        // the *task* is not): only a per-task wall-clock budget can stop
        // it. 60s is far beyond any test timeout, so if the budget ever
        // fails to fire, the suite hangs loudly instead of passing.
        "hang5" if i == 5 && ctx.attempt == 1 => {
            std::thread::sleep(Duration::from_secs(60));
            Ok(Json::int(-1))
        }
        _ => Ok(Json::int(i * 10)),
    }
}

/// Worker entry: spawned via `--exact ipc_worker_entry`. Does nothing in
/// a normal test pass.
#[test]
fn ipc_worker_entry() {
    if !memento::ipc::worker::active() {
        return;
    }
    memento::ipc::worker::serve(Arc::new(Registry::solo(Arc::new(exp)))).expect("worker serve");
    std::process::exit(0);
}

fn matrix(n: i64, mode: &str) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n).map(pv_int).collect())
        .setting("mode", Json::str(mode))
        .build()
        .unwrap()
}

fn process_memento(workers: usize, crash_budget: u32) -> Memento {
    Memento::new(exp)
        .isolate_processes(workers, crash_budget)
        .worker_args(vec!["--exact".to_string(), "ipc_worker_entry".to_string()])
}

#[test]
fn process_backend_matches_thread_backend() {
    let m = matrix(8, "ok");
    let threads = Memento::new(exp).workers(3).run(&m).unwrap();
    let procs = process_memento(3, 1).run(&m).unwrap();
    assert_eq!(procs.len(), threads.len());
    assert_eq!(procs.n_failed(), 0);
    for (t, p) in threads.iter().zip(procs.iter()) {
        assert_eq!(t.spec.get("i"), p.spec.get("i"));
        assert_eq!(t.value, p.value, "i={:?}", t.spec.get("i"));
        assert_eq!(t.id, p.id, "task identity must be backend-independent");
    }
}

#[test]
fn process_backend_reports_contained_failures() {
    let results = process_memento(2, 1).run(&matrix(4, "fail2")).unwrap();
    assert_eq!(results.len(), 4);
    assert_eq!(results.n_failed(), 1);
    let f = results
        .failures()
        .next()
        .unwrap()
        .failure
        .clone()
        .unwrap();
    assert_eq!(f.kind, FailureKind::Error);
    assert!(f.message.contains("i=2"), "{}", f.message);
}

/// The acceptance-criterion test: a worker dies (uncatchable `abort`,
/// equivalent to `kill -9`) mid-task. The run must complete with
/// exactly-once results identical to a thread run, correct retry/skip
/// metrics, and a coherent journal for the victim:
/// started → failed → started → succeeded.
#[test]
fn process_backend_survives_killed_worker() {
    let td = TempDir::new("ipc-crash").unwrap();
    let jpath = td.join("journal.jsonl");
    let m = matrix(8, "crash3");

    let builder = process_memento(2, 2)
        .with_retry(RetryPolicy::fixed(2, Duration::ZERO))
        .with_journal(&jpath)
        .seed(7);
    let metrics = builder.metrics();
    let results = builder.run(&m).unwrap();

    // Exactly-once, fully successful, values identical to a thread run.
    assert_eq!(results.len(), 8);
    assert_eq!(results.n_failed(), 0);
    let reference = Memento::new(exp).workers(2).run(&matrix(8, "ok")).unwrap();
    for (r, t) in results.iter().zip(reference.iter()) {
        assert_eq!(r.spec.get("i"), t.spec.get("i"));
        assert_eq!(r.value, t.value);
    }
    let victim = results.find(&[("i", pv_int(3))]).unwrap();
    assert_eq!(victim.attempts, 2, "victim must have taken two attempts");

    // Metrics: one crash-requeue, nothing skipped, everything counted.
    assert_eq!(metrics.tasks_retried.get(), 1);
    assert_eq!(metrics.tasks_skipped.get(), 0);
    assert_eq!(metrics.tasks_total.get(), 8);
    assert_eq!(metrics.tasks_succeeded.get(), 8);

    // Journal: the victim's lifecycle is started(1) → failed(1, crash) →
    // started(2) → succeeded(2); every other task succeeds exactly once,
    // and no task records duplicate outcomes.
    let events = Journal::replay(&jpath).unwrap();
    let victim_events: Vec<&Event> = events
        .iter()
        .map(|(_, e)| e)
        .filter(|e| match e {
            Event::TaskStarted { id, .. }
            | Event::TaskSucceeded { id, .. }
            | Event::TaskFailed { id, .. } => *id == victim.id,
            _ => false,
        })
        .collect();
    assert_eq!(victim_events.len(), 4, "{victim_events:?}");
    assert!(
        matches!(victim_events[0], Event::TaskStarted { attempt: 1, .. }),
        "{victim_events:?}"
    );
    match victim_events[1] {
        Event::TaskFailed { attempt: 1, message, .. } => {
            assert!(message.contains("died"), "crash message: {message}");
        }
        other => panic!("expected crash TaskFailed, got {other:?}"),
    }
    assert!(
        matches!(victim_events[2], Event::TaskStarted { attempt: 2, .. }),
        "{victim_events:?}"
    );
    assert!(
        matches!(victim_events[3], Event::TaskSucceeded { attempt: 2, .. }),
        "{victim_events:?}"
    );

    let mut succeeded_ids: Vec<String> = events
        .iter()
        .filter_map(|(_, e)| match e {
            Event::TaskSucceeded { id, .. } => Some(id.0.clone()),
            _ => None,
        })
        .collect();
    succeeded_ids.sort();
    let before = succeeded_ids.len();
    succeeded_ids.dedup();
    assert_eq!(before, 8, "8 success events, one per task");
    assert_eq!(succeeded_ids.len(), 8, "no duplicate outcomes journaled");
}

/// The per-task wall-clock budget: a sleeper task (healthy worker,
/// heartbeats flowing, task stuck) is killed at `--task-timeout`,
/// journaled as a **timeout** (its own journal kind, not a crash or a
/// plain failure), and requeued exactly once under the retry policy; the
/// second attempt succeeds. The kill must not consume worker crash
/// budget (crash_budget is 0 here: any crash-charged kill would retire
/// the slot and fail the run).
#[test]
fn hung_task_is_killed_at_timeout_and_requeued_exactly_once() {
    let td = TempDir::new("ipc-timeout").unwrap();
    let jpath = td.join("journal.jsonl");
    let m = matrix(8, "hang5");

    let builder = process_memento(2, 0)
        .task_timeout(Duration::from_millis(500))
        .with_retry(RetryPolicy::fixed(2, Duration::ZERO))
        .with_journal(&jpath);
    let metrics = builder.metrics();
    let started = std::time::Instant::now();
    let results = builder.run(&m).unwrap();
    assert!(
        started.elapsed() < Duration::from_secs(30),
        "the 60s sleeper must have been stopped at its 500ms budget"
    );

    // Exactly-once: every task succeeded; the victim took two attempts.
    assert_eq!(results.len(), 8);
    assert_eq!(results.n_failed(), 0);
    let victim = results.find(&[("i", pv_int(5))]).unwrap();
    assert_eq!(victim.attempts, 2, "timed out once, requeued exactly once");
    assert_eq!(victim.value.as_ref().and_then(|v| v.as_i64()), Some(50));

    // Metrics: one timeout, one retry, no skips, everything counted.
    assert_eq!(metrics.tasks_timed_out.get(), 1);
    assert_eq!(metrics.tasks_retried.get(), 1);
    assert_eq!(metrics.tasks_skipped.get(), 0);
    assert_eq!(metrics.tasks_succeeded.get(), 8);

    // Journal: started(1) → timed_out(1, budget) → started(2) →
    // succeeded(2), and the timeout is its own kind — not a failed
    // attempt.
    let events = Journal::replay(&jpath).unwrap();
    let victim_events: Vec<&Event> = events
        .iter()
        .map(|(_, e)| e)
        .filter(|e| match e {
            Event::TaskStarted { id, .. }
            | Event::TaskSucceeded { id, .. }
            | Event::TaskFailed { id, .. }
            | Event::TaskTimedOut { id, .. } => *id == victim.id,
            _ => false,
        })
        .collect();
    assert_eq!(victim_events.len(), 4, "{victim_events:?}");
    assert!(
        matches!(victim_events[0], Event::TaskStarted { attempt: 1, .. }),
        "{victim_events:?}"
    );
    match victim_events[1] {
        Event::TaskTimedOut { attempt: 1, budget_secs, .. } => {
            assert!((budget_secs - 0.5).abs() < 1e-9, "budget recorded: {budget_secs}");
        }
        other => panic!("expected TaskTimedOut, got {other:?}"),
    }
    assert!(
        matches!(victim_events[2], Event::TaskStarted { attempt: 2, .. }),
        "{victim_events:?}"
    );
    assert!(
        matches!(victim_events[3], Event::TaskSucceeded { attempt: 2, .. }),
        "{victim_events:?}"
    );
    let s = Journal::summarize(&jpath).unwrap();
    assert_eq!(s.timeouts, 1);
    assert_eq!(s.failed_attempts, 0, "a timeout is not journaled as a failure");
}

/// Fail-fast must work across the process boundary too: after the first
/// failure the supervisor stops dispatching and skips the remainder.
#[test]
fn process_backend_fail_fast_aborts_and_skips() {
    let m = matrix(12, "fail2");
    let err = process_memento(1, 1)
        .fail_fast(true)
        .run(&m)
        .unwrap_err();
    assert!(matches!(err, MementoError::Aborted(_)), "{err}");
}

// ---- experiment-capability routing (protocol v5) ------------------------

/// Worker entry with a *named* registry: registers `alpha` (and keeps the
/// unnamed fallback), so its Ready frame advertises `["alpha"]`. Spawned
/// via `--exact ipc_named_worker_entry`; no-op in a normal pass.
#[test]
fn ipc_named_worker_entry() {
    if !memento::ipc::worker::active() {
        return;
    }
    let registry = Registry::new()
        .register("alpha", "a1", "process capability test", exp)
        .register_default(exp);
    memento::ipc::worker::serve(Arc::new(registry)).expect("worker serve");
    std::process::exit(0);
}

/// A matrix whose every row names the `alpha` experiment via the
/// reserved `exp` parameter.
fn named_matrix(n: i64) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("exp", vec![pv_str("alpha")])
        .param("i", (0..n).map(pv_int).collect())
        .setting("mode", Json::str("ok"))
        .build()
        .unwrap()
}

fn named_registry() -> Registry {
    Registry::new()
        .register("alpha", "a1", "process capability test", exp)
        .register_default(exp)
}

/// Positive path: a process worker that registered `alpha` serves the
/// alpha-named tasks, with identity matching the thread backend.
#[test]
fn named_tasks_route_to_capable_process_workers() {
    let m = named_matrix(6);
    let threads = Memento::with_registry(named_registry()).workers(3).run(&m).unwrap();
    let procs = Memento::with_registry(named_registry())
        .isolate_processes(2, 1)
        .worker_args(vec!["--exact".to_string(), "ipc_named_worker_entry".to_string()])
        .run(&m)
        .unwrap();
    assert_eq!(procs.len(), 6);
    assert_eq!(procs.n_failed(), 0);
    for (t, p) in threads.iter().zip(procs.iter()) {
        assert_eq!(t.id, p.id, "named-task identity must be backend-independent");
        assert_eq!(t.value, p.value);
    }
}

/// Capability-departure parity with the remote backend: the solo worker
/// entry advertises an empty capability list, so alpha-named tasks have
/// no capable worker. They fail as typed `unknown-experiment` with the
/// reason journaled — the crash budget is never touched and the run
/// never hangs.
#[test]
fn named_tasks_fail_explicitly_on_capability_less_process_worker() {
    let td = TempDir::new("ipc-unservable").unwrap();
    let jpath = td.join("unservable.jsonl");
    let results = Memento::with_registry(named_registry())
        .isolate_processes(2, 1)
        .worker_args(vec!["--exact".to_string(), "ipc_worker_entry".to_string()])
        .with_journal(&jpath)
        .run(&named_matrix(4))
        .unwrap();
    assert_eq!(results.len(), 4);
    assert_eq!(results.n_failed(), 4, "every named task is unservable");
    for o in results.iter() {
        let f = o.failure.as_ref().expect("typed failure");
        assert_eq!(f.kind, FailureKind::UnknownExperiment);
        assert!(
            f.message.contains("no live worker registers experiment 'alpha'"),
            "{}",
            f.message
        );
    }
    let text = std::fs::read_to_string(&jpath).unwrap();
    assert!(text.contains("no live worker registers experiment 'alpha'"), "{text}");
    let summary = Journal::summarize(&jpath).unwrap();
    assert_eq!(summary.started, 0, "unservable tasks never start");
    assert_eq!(summary.failed_attempts, 4, "{summary:?}");
}
