//! Integration tests for the observability layer (`memento::obs`):
//! backend parity of span timelines, live telemetry events, the
//! persisted final snapshot, and graceful degradation against a pre-v4
//! (no exec-timestamp) remote peer.
//!
//! The backend-parity test runs the same matrix over in-process
//! threads, spawned worker processes, and loopback-TCP remote workers,
//! and requires every executed attempt to carry the full
//! `queued → dispatched → exec_start → exec_end → recorded` sequence
//! with zero dropped spans on all three tiers.

#![cfg(unix)]

use memento::coordinator::memento::ExpFn;
use memento::coordinator::run::RunEvent;
use memento::ipc::pool::{PoolOptions, WorkerPool};
use memento::ipc::transport::Transport;
use memento::ipc::worker::{serve_remote, RemoteServeReport, RemoteWorkerOptions};
use memento::obs::snapshot::read_snapshot;
use memento::obs::trace::{read_trace, TraceFile, TRACE_FILE};
use memento::prelude::*;
use memento::util::codec::WireFormat;
use memento::util::fs::TempDir;
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

const TOKEN: &str = "obs-trace-token";

fn exp(ctx: &TaskContext) -> Result<Json, MementoError> {
    let i = ctx.param_i64("i")?;
    Ok(Json::int(i * 10))
}

/// Worker entry for the spawned-process run: the supervisor re-executes
/// this test binary with a libtest filter selecting this function. No-op
/// in a normal test pass.
#[test]
fn obs_trace_worker_entry() {
    if !memento::ipc::worker::active() {
        return;
    }
    memento::ipc::worker::serve(Arc::new(Registry::solo(Arc::new(exp)))).expect("worker serve");
    std::process::exit(0);
}

fn matrix(n: i64) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n).map(pv_int).collect())
        .build()
        .unwrap()
}

fn tcp_pool() -> Arc<WorkerPool> {
    WorkerPool::listen(
        &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
        PoolOptions { token: Some(TOKEN.to_string()), ..PoolOptions::default() },
    )
    .unwrap()
}

fn spawn_worker(
    pool: &Arc<WorkerPool>,
    max_connections: Option<usize>,
) -> JoinHandle<Result<RemoteServeReport, MementoError>> {
    let endpoint = pool.endpoint().clone();
    std::thread::spawn(move || {
        let exp_fn: Arc<ExpFn> = Arc::new(exp);
        serve_remote(
            Arc::new(Registry::solo(exp_fn)),
            &endpoint,
            RemoteWorkerOptions {
                token: Some(TOKEN.to_string()),
                max_connections,
                give_up_after: Some(Duration::from_secs(1)),
                quiet: true,
                ..RemoteWorkerOptions::default()
            },
        )
    })
}

/// Per-attempt view of a trace: the first timestamp seen for each state.
type Timelines = BTreeMap<(u64, u32), BTreeMap<&'static str, u64>>;

fn timelines(trace: &TraceFile) -> Timelines {
    let mut map: Timelines = BTreeMap::new();
    for ev in &trace.spans {
        map.entry((ev.index, ev.attempt))
            .or_default()
            .entry(ev.state.as_str())
            .or_insert(ev.t_us);
    }
    map
}

/// The acceptance gate shared by every backend: the trace is sealed
/// (footer present, counts match, zero drops) and every one of the `n`
/// tasks has an executed attempt carrying the full five-state sequence.
fn assert_complete_trace(dir: &Path, n: usize) -> TraceFile {
    let trace = read_trace(&dir.join(TRACE_FILE)).expect("read trace");
    assert_eq!(trace.dropped, Some(0), "zero dropped spans");
    assert_eq!(
        trace.footer_spans.map(|s| s as usize),
        Some(trace.spans.len()),
        "footer count must match the spans on disk"
    );
    assert!(trace.header.is_some(), "header record present");

    let tls = timelines(&trace);
    let executed: Vec<_> = tls.iter().filter(|((_, attempt), _)| *attempt >= 1).collect();
    assert_eq!(executed.len(), n, "one executed attempt per task");
    let indices: BTreeSet<u64> = executed.iter().map(|((i, _), _)| *i).collect();
    assert_eq!(indices.len(), n, "every task index appears");
    for ((i, a), tl) in executed {
        for need in ["queued", "dispatched", "exec_start", "exec_end", "recorded"] {
            assert!(tl.contains_key(need), "task {i} attempt {a} missing {need}: {tl:?}");
        }
        assert!(
            tl["exec_end"] >= tl["exec_start"],
            "task {i} attempt {a}: exec window inverted ({tl:?})"
        );
    }
    trace
}

/// The tentpole acceptance test: the same 20-task run on all three
/// execution tiers produces a complete, merged span timeline — remote
/// exec timestamps land on the coordinator's clock axis via the
/// per-worker offset estimated at the Ready exchange.
#[test]
fn all_three_backends_produce_complete_span_timelines() {
    let td = TempDir::new("obs-parity").unwrap();
    let m = matrix(20);

    let tdir = td.join("threads");
    let results = Memento::new(exp).workers(3).trace_to(&tdir).run(&m).unwrap();
    assert_eq!(results.n_failed(), 0);
    assert_complete_trace(&tdir, 20);

    let pdir = td.join("process");
    let results = Memento::new(exp)
        .isolate_processes(2, 1)
        .worker_args(vec!["--exact".to_string(), "obs_trace_worker_entry".to_string()])
        .trace_to(&pdir)
        .run(&m)
        .unwrap();
    assert_eq!(results.n_failed(), 0);
    assert_complete_trace(&pdir, 20);

    let rdir = td.join("remote");
    let pool = tcp_pool();
    let worker = spawn_worker(&pool, Some(1));
    let results = Memento::new(exp)
        .with_worker_pool(Arc::clone(&pool))
        .remote_workers("unused: pool owns the listener", 1)
        .trace_to(&rdir)
        .run(&m)
        .unwrap();
    pool.shutdown();
    worker.join().unwrap().unwrap();
    assert_eq!(results.n_failed(), 0);
    let trace = assert_complete_trace(&rdir, 20);
    // Remote exec spans are attributed to the worker that ran them.
    for ev in &trace.spans {
        if matches!(ev.state, SpanState::ExecStart | SpanState::ExecEnd) {
            assert!(ev.worker.is_some(), "remote exec span missing worker id: {ev:?}");
        }
    }
}

/// Restored tasks get the short `queued → restored → recorded` timeline
/// (attempt 0) instead of an execution window.
#[test]
fn restored_tasks_trace_the_restore_timeline() {
    let td = TempDir::new("obs-restore").unwrap();
    let cache = td.join("cache");
    let m = matrix(6);
    Memento::new(exp).workers(2).with_cache_dir(&cache).run(&m).unwrap();

    let tdir = td.join("trace");
    let results = Memento::new(exp)
        .workers(2)
        .with_cache_dir(&cache)
        .trace_to(&tdir)
        .run(&m)
        .unwrap();
    assert_eq!(results.n_cached(), 6);

    let trace = read_trace(&tdir.join(TRACE_FILE)).expect("read trace");
    assert_eq!(trace.dropped, Some(0));
    let tls = timelines(&trace);
    assert_eq!(tls.len(), 6, "one attempt-0 timeline per restored task");
    for ((i, attempt), tl) in &tls {
        assert_eq!(*attempt, 0, "restores record attempt 0");
        for need in ["queued", "restored", "recorded"] {
            assert!(tl.contains_key(need), "task {i} missing {need}: {tl:?}");
        }
        assert!(!tl.contains_key("exec_start"), "restores never execute");
    }
}

/// Live telemetry: the sampler emits coalescable `Telemetry` events
/// while the run is in flight, the terminal `RunSummary` carries the
/// full final snapshot, and the snapshot is persisted beside the trace
/// for `memento status`.
#[test]
fn telemetry_streams_and_final_snapshot_lands_everywhere() {
    let td = TempDir::new("obs-telemetry").unwrap();
    let tdir = td.join("trace");
    let slow: fn(&TaskContext) -> Result<Json, MementoError> = |ctx| {
        std::thread::sleep(Duration::from_millis(10));
        Ok(Json::int(ctx.param_i64("i")?))
    };
    let run = Memento::new(slow)
        .workers(4)
        .telemetry_every(Duration::from_millis(5))
        .trace_to(&tdir)
        .launch(&matrix(20))
        .unwrap();

    let mut telemetry = 0usize;
    let mut last_snapshot = None;
    let mut final_summary = None;
    for event in run.events() {
        match event {
            RunEvent::Telemetry(snap) => {
                telemetry += 1;
                last_snapshot = Some(snap);
            }
            RunEvent::RunComplete(summary) => final_summary = Some(summary),
            _ => {}
        }
    }
    run.collect().unwrap();

    assert!(telemetry >= 1, "sampler fired at least once");
    let live = last_snapshot.expect("at least one live snapshot");
    assert!(live.tasks_total <= 20);

    let summary = final_summary.expect("RunComplete observed");
    let metrics = summary.metrics.expect("final snapshot on the summary");
    assert_eq!(metrics.tasks_succeeded, 20);
    assert_eq!(metrics.queue_depth, 0, "nothing outstanding at the end");
    assert!(!metrics.workers.is_empty(), "fleet rows populated");
    assert!(metrics.workers.iter().map(|w| w.completed).sum::<u64>() >= 20);

    let persisted = read_snapshot(&tdir).expect("metrics.snap beside the trace");
    assert_eq!(persisted.tasks_succeeded, 20);
}

/// Reads one frame the way the JSON-wire peer below does: length
/// prefix, then a payload that must be JSON text (the run is pinned to
/// `--wire json`, so a binary frame here is a bug).
fn read_json_frame(r: &mut dyn std::io::Read) -> Option<memento::ipc::proto::Msg> {
    use std::io::Read as _;
    let mut len = [0u8; 4];
    if r.read_exact(&mut len).is_err() {
        return None; // connection closed after Shutdown
    }
    let mut payload = vec![0u8; u32::from_be_bytes(len) as usize];
    r.read_exact(&mut payload).unwrap();
    assert_ne!(
        payload[0],
        memento::util::codec::BINARY_MAGIC,
        "supervisor sent a binary frame on a JSON-wire run"
    );
    let text = std::str::from_utf8(&payload).expect("JSON frames are UTF-8");
    memento::ipc::proto::Msg::from_json(&memento::util::json::parse(text).unwrap())
}

/// Protocol degradation: a v3 peer — registers without `clock_us`,
/// returns outcomes without exec timestamps — still completes a traced
/// run. The supervisor synthesizes the exec window from the reported
/// `duration_secs` on its own clock, so the timeline stays complete.
#[test]
fn v3_peer_without_exec_timestamps_degrades_to_synthesized_spans() {
    use memento::ipc::proto::{write_frame, Msg, WireResult};

    let td = TempDir::new("obs-v3").unwrap();
    let pool = tcp_pool();
    let endpoint = pool.endpoint().clone();
    let worker = std::thread::spawn(move || -> usize {
        let mut stream = endpoint.connect().unwrap();
        let mut writer = stream.try_clone_stream().unwrap();
        write_frame(
            &mut writer,
            &Msg::Ready {
                worker: 77,
                pid: std::process::id() as u64,
                spawn: 0,
                protocol: 3, // pre-observability peer
                token: Some(TOKEN.to_string()),
                clock_us: None, // v3 never reports its clock
                exps: None,     // …and predates the experiment registry
            },
        )
        .unwrap();
        let mut tasks = 0usize;
        loop {
            match read_json_frame(&mut stream) {
                Some(Msg::Hello { protocol, .. }) => {
                    assert_eq!(protocol, 3, "negotiated down to the peer's version");
                }
                Some(Msg::Task { index, attempt, params, .. }) => {
                    let i = params
                        .iter()
                        .find(|(k, _)| k == "i")
                        .and_then(|(_, v)| v.to_json().as_i64())
                        .unwrap();
                    tasks += 1;
                    std::thread::sleep(Duration::from_millis(5));
                    write_frame(
                        &mut writer,
                        &Msg::Outcome {
                            index,
                            attempt,
                            duration_secs: 0.005,
                            exec_start_us: None, // v3 frames carry no exec window
                            exec_end_us: None,
                            result: WireResult::Ok { value: Json::int(i * 10) },
                        },
                    )
                    .unwrap();
                }
                Some(Msg::Shutdown) | None => break,
                other => panic!("unexpected frame at the v3 worker: {other:?}"),
            }
        }
        tasks
    });

    let tdir = td.join("trace");
    let results = Memento::new(exp)
        .with_worker_pool(Arc::clone(&pool))
        .remote_workers("unused: pool owns the listener", 1)
        .wire_format(WireFormat::Json)
        .trace_to(&tdir)
        .run(&matrix(6))
        .unwrap();
    pool.shutdown();
    assert_eq!(worker.join().unwrap(), 6, "the v3 worker executed every task");
    assert_eq!(results.n_failed(), 0);

    let trace = assert_complete_trace(&tdir, 6);
    // Synthesized windows are duration_secs wide on the supervisor's
    // clock (the reported 5ms, give or take float rounding).
    let tls = timelines(&trace);
    for ((i, attempt), tl) in tls.iter().filter(|((_, a), _)| *a >= 1) {
        let width = tl["exec_end"] - tl["exec_start"];
        assert!(
            (4_000..=6_000).contains(&width),
            "task {i} attempt {attempt}: synthesized exec window {width}us"
        );
    }
}
