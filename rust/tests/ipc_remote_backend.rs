//! Integration tests for the distributed execution backend
//! ([`memento::ipc::pool`] + `ExecBackend::Remote`): standing workers
//! over loopback TCP, token auth, mid-run connection churn, and parity
//! with the thread and process backends.
//!
//! "Remote" workers here are in-process threads running
//! [`memento::ipc::worker::serve_remote`] against a loopback TCP pool —
//! the exact code path `memento serve` uses, minus the process boundary
//! (which the process-backend suite already covers). Every worker is
//! bounded (`max_connections` / `give_up_after`) so threads always join.

#![cfg(unix)]

use memento::coordinator::journal::Journal;
use memento::coordinator::memento::ExpFn;
use memento::ipc::pool::{PoolOptions, WorkerPool};
use memento::ipc::transport::Transport;
use memento::ipc::worker::{serve_remote, RemoteServeReport, RemoteWorkerOptions};
use memento::prelude::*;
use memento::util::fs::TempDir;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

const TOKEN: &str = "remote-test-token";

/// The experiment function shared by the supervisor-side runs and every
/// worker (thread, spawned process, and remote alike) — task identity
/// hashes params + version, so all backends agree on ids.
fn exp(ctx: &TaskContext) -> Result<Json, MementoError> {
    let i = ctx.param_i64("i")?;
    Ok(Json::int(i * 10))
}

/// Worker entry for the spawned-process comparison run (see
/// `tests/ipc_process_backend.rs` for the pattern). No-op in a normal
/// test pass.
#[test]
fn remote_ipc_worker_entry() {
    if !memento::ipc::worker::active() {
        return;
    }
    memento::ipc::worker::serve(Arc::new(Registry::solo(Arc::new(exp)))).expect("worker serve");
    std::process::exit(0);
}

fn matrix(n: i64) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n).map(pv_int).collect())
        .build()
        .unwrap()
}

fn tcp_pool() -> Arc<WorkerPool> {
    WorkerPool::listen(
        &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
        PoolOptions { token: Some(TOKEN.to_string()), ..PoolOptions::default() },
    )
    .unwrap()
}

/// Spawns an in-process standing worker thread. Bounded: it exits after
/// `max_connections` served runs, or once the pool has been gone for a
/// second — so tests can always join it.
fn spawn_worker(
    pool: &Arc<WorkerPool>,
    token: &str,
    max_connections: Option<usize>,
    tasks_per_connection: Option<usize>,
) -> JoinHandle<Result<RemoteServeReport, MementoError>> {
    let endpoint = pool.endpoint().clone();
    let token = token.to_string();
    std::thread::spawn(move || {
        let exp_fn: Arc<ExpFn> = Arc::new(exp);
        serve_remote(
            Arc::new(Registry::solo(exp_fn)),
            &endpoint,
            RemoteWorkerOptions {
                token: Some(token),
                max_connections,
                tasks_per_connection,
                give_up_after: Some(Duration::from_secs(1)),
                quiet: true,
                ..RemoteWorkerOptions::default()
            },
        )
    })
}

fn remote_memento(pool: &Arc<WorkerPool>, workers: usize) -> Memento {
    Memento::new(exp)
        .with_worker_pool(Arc::clone(pool))
        .remote_workers("unused: pool owns the listener", workers)
}

/// The headline acceptance test: the same matrix over in-process threads,
/// spawned worker processes (Unix socket), and remote workers (loopback
/// TCP) yields identical ResultSets — same task ids, same values — and
/// identical journal accounting (8 started, 8 succeeded, nothing failed,
/// retried, or restored, on every backend).
#[test]
fn tcp_remote_backend_matches_thread_and_process_backends() {
    let td = TempDir::new("remote-parity").unwrap();
    let m = matrix(8);

    let run_with = |label: &str, builder: Memento| {
        let jpath = td.join(format!("{label}.jsonl"));
        let results = builder.with_journal(&jpath).run(&m).unwrap();
        let summary = Journal::summarize(&jpath).unwrap();
        (results, summary)
    };

    let (threads, tj) = run_with("threads", Memento::new(exp).workers(3));
    let (procs, pj) = run_with(
        "process",
        Memento::new(exp)
            .isolate_processes(2, 1)
            .worker_args(vec!["--exact".to_string(), "remote_ipc_worker_entry".to_string()]),
    );

    let pool = tcp_pool();
    let w1 = spawn_worker(&pool, TOKEN, Some(1), None);
    let w2 = spawn_worker(&pool, TOKEN, Some(1), None);
    let (remote, rj) = run_with("remote", remote_memento(&pool, 2));
    pool.shutdown();
    let (r1, r2) = (w1.join().unwrap().unwrap(), w2.join().unwrap().unwrap());
    assert_eq!(r1.tasks + r2.tasks, 8, "remote workers executed every task");

    for results in [&threads, &procs, &remote] {
        assert_eq!(results.len(), 8);
        assert_eq!(results.n_failed(), 0);
        assert_eq!(results.n_cached(), 0);
    }
    for (t, r) in threads.iter().zip(remote.iter()) {
        assert_eq!(t.spec.get("i"), r.spec.get("i"));
        assert_eq!(t.value, r.value, "i={:?}", t.spec.get("i"));
        assert_eq!(t.id, r.id, "task identity must be backend-independent");
    }
    for (p, r) in procs.iter().zip(remote.iter()) {
        assert_eq!(p.id, r.id);
        assert_eq!(p.value, r.value);
    }
    // Exactly-once journal accounting, identical across all three tiers.
    for summary in [&tj, &pj, &rj] {
        assert_eq!(summary.started, 8, "{summary:?}");
        assert_eq!(summary.succeeded, 8, "{summary:?}");
        assert_eq!(summary.failed_attempts, 0, "{summary:?}");
        assert_eq!(summary.timeouts, 0, "{summary:?}");
        assert_eq!(summary.restored, 0, "{summary:?}");
    }
}

/// A worker presenting the wrong token is refused at the handshake with
/// an explicit `Reject` — it never serves a task, the pool counts the
/// refusal, and a correctly-authenticated worker still serves the run.
#[test]
fn bad_token_worker_is_rejected_and_never_serves() {
    let pool = tcp_pool();

    let imposter = spawn_worker(&pool, "wrong-token", Some(1), None);
    let err = imposter.join().unwrap().unwrap_err();
    assert!(
        err.to_string().contains("rejected") && err.to_string().contains("token"),
        "worker must surface the refusal reason, got: {err}"
    );
    assert_eq!(pool.rejected_count(), 1);
    assert_eq!(pool.registered_count(), 0);

    // The pool remains healthy for authenticated workers.
    let honest = spawn_worker(&pool, TOKEN, Some(1), None);
    let results = remote_memento(&pool, 1).run(&matrix(4)).unwrap();
    assert_eq!(results.len(), 4);
    assert_eq!(results.n_failed(), 0);
    pool.shutdown();
    let report = honest.join().unwrap().unwrap();
    assert_eq!(report.tasks, 4);
    assert_eq!(pool.rejected_count(), 1, "no further rejections");
}

/// Mid-run connection churn: a single worker that departs (clean
/// `Goodbye`) after every third task and re-registers must carry a
/// 10-task run to completion exactly-once — 4 connections, no failed
/// attempts, no retries consumed.
#[test]
fn rolling_worker_reconnects_mid_run_without_losing_work() {
    let td = TempDir::new("remote-churn").unwrap();
    let jpath = td.join("journal.jsonl");
    let pool = tcp_pool();
    // 3 + 3 + 3 + 1 tasks ⇒ exactly 4 connections.
    let worker = spawn_worker(&pool, TOKEN, Some(4), Some(3));

    let results = remote_memento(&pool, 1)
        .with_journal(&jpath)
        .run(&matrix(10))
        .unwrap();
    assert_eq!(results.len(), 10);
    assert_eq!(results.n_failed(), 0);
    for o in results.iter() {
        assert_eq!(o.attempts, 1, "churn must not consume retry attempts");
    }

    let report = worker.join().unwrap().unwrap();
    assert_eq!(report.tasks, 10);
    assert_eq!(report.connections, 4, "re-registered after every 3rd task");
    assert_eq!(pool.registered_count(), 4);

    // Exactly-once accounting: every task succeeded exactly once and no
    // attempt was journaled as failed (a `Goodbye` departure re-queues
    // the crossed dispatch without consuming it). Re-dispatched attempts
    // may repeat a `started` line; they never duplicate outcomes.
    let summary = Journal::summarize(&jpath).unwrap();
    assert_eq!(summary.succeeded, 10);
    assert!(summary.started >= 10);
    assert_eq!(summary.failed_attempts, 0);
}

/// The pool outlives `run()`: two consecutive runs against the same pool
/// are served by the *same* standing worker, which re-registers between
/// them — worker startup cost is paid once, not per run.
#[test]
fn standing_pool_serves_consecutive_runs_with_the_same_worker() {
    let pool = tcp_pool();
    let worker = spawn_worker(&pool, TOKEN, Some(2), None);

    let first = remote_memento(&pool, 1).run(&matrix(4)).unwrap();
    assert_eq!(first.len(), 4);
    assert_eq!(first.n_failed(), 0);

    let second = remote_memento(&pool, 1).run(&matrix(3)).unwrap();
    assert_eq!(second.len(), 3);
    assert_eq!(second.n_failed(), 0);

    let report = worker.join().unwrap().unwrap();
    assert_eq!(report.connections, 2, "one worker served both runs");
    assert_eq!(report.tasks, 7);
    assert_eq!(pool.registered_count(), 2, "initial registration + one re-registration");
}

/// Reads one frame the way a genuine v2 peer would: length prefix, then
/// a payload that must be JSON text — a v2 binary has no idea what the
/// binary magic means, so receiving it is an instant failure here.
/// Returns `None` on a clean close.
fn read_v2_frame(r: &mut dyn std::io::Read) -> Option<memento::ipc::proto::Msg> {
    let mut len = [0u8; 4];
    if r.read_exact(&mut len).is_err() {
        return None; // connection closed after Shutdown
    }
    let mut payload = vec![0u8; u32::from_be_bytes(len) as usize];
    r.read_exact(&mut payload).unwrap();
    assert_ne!(
        payload[0],
        memento::util::codec::BINARY_MAGIC,
        "v3 supervisor sent a binary frame to a v2 peer"
    );
    let text = std::str::from_utf8(&payload).expect("v2 frames are UTF-8 JSON");
    memento::ipc::proto::Msg::from_json(&memento::util::json::parse(text).unwrap())
}

/// Backward compatibility with pre-binary peers: a faithful v2 worker —
/// registers with `protocol: 2`, writes only JSON frames, panics on any
/// binary frame, and (like the shipped v2 code) would reject a Hello
/// that does not say v2 — completes an entire run against a v3 pool
/// whose supervisor defaults to binary framing.
#[test]
fn v2_json_only_worker_completes_a_run_against_a_v3_pool() {
    use memento::ipc::proto::{write_frame, Msg, WireResult};

    let pool = tcp_pool();
    let endpoint = pool.endpoint().clone();
    let worker = std::thread::spawn(move || -> usize {
        let mut stream = endpoint.connect().unwrap();
        let mut writer = stream.try_clone_stream().unwrap();
        write_frame(
            &mut writer,
            &Msg::Ready {
                worker: 91,
                pid: std::process::id() as u64,
                spawn: 0,
                protocol: 2, // the v2 declaration under test
                token: Some(TOKEN.to_string()),
                clock_us: None, // v2 predates the observability fields
                exps: None,     // …and the experiment registry
            },
        )
        .unwrap();
        let mut tasks = 0usize;
        loop {
            match read_v2_frame(&mut stream) {
                Some(Msg::Hello { protocol, .. }) => {
                    // The shipped v2 worker errors on `protocol != 2`; the
                    // v3 supervisor must advertise the negotiated version.
                    assert_eq!(protocol, 2, "v2 worker would reject this Hello");
                }
                Some(Msg::Task { index, attempt, params, .. }) => {
                    let i = params
                        .iter()
                        .find(|(k, _)| k == "i")
                        .and_then(|(_, v)| v.to_json().as_i64())
                        .unwrap();
                    tasks += 1;
                    write_frame(
                        &mut writer,
                        &Msg::Outcome {
                            index,
                            attempt,
                            duration_secs: 0.01,
                            exec_start_us: None,
                            exec_end_us: None,
                            result: WireResult::Ok { value: Json::int(i * 10) },
                        },
                    )
                    .unwrap();
                }
                Some(Msg::Shutdown) | None => break,
                other => panic!("unexpected frame at a v2 worker: {other:?}"),
            }
        }
        tasks
    });

    let results = remote_memento(&pool, 1).run(&matrix(5)).unwrap();
    pool.shutdown();
    assert_eq!(worker.join().unwrap(), 5, "the v2 worker executed every task");

    assert_eq!(results.len(), 5);
    assert_eq!(results.n_failed(), 0);
    for o in results.iter() {
        let i = o.spec.get("i").and_then(|v| v.to_json().as_i64()).unwrap();
        assert_eq!(o.value, Some(Json::int(i * 10)));
    }
    assert_eq!(pool.rejected_count(), 0, "v2 registration must be admitted");
}

/// A remote run with no registered workers must fail explicitly (every
/// slot retires after its lease window) rather than hang — nothing is
/// silently dropped.
#[test]
fn remote_run_without_workers_fails_explicitly() {
    // Exercised through the supervisor directly so the lease window can
    // be short; the Memento surface uses the same path with its default.
    use memento::coordinator::source::SpecSource;
    use memento::ipc::supervisor::{self, SupervisorHooks, SupervisorOptions, WorkerSource};
    use std::collections::BTreeMap;

    let pool = tcp_pool();
    let specs = memento::coordinator::expand::expand(&matrix(3));
    let source: SpecSource = Box::new(specs.into_iter());
    let completed = Arc::new(std::sync::Mutex::new(Vec::new()));
    let record = {
        let completed = Arc::clone(&completed);
        Arc::new(move |o: &TaskOutcome| {
            completed.lock().unwrap().push(o.clone());
        }) as Arc<dyn Fn(&TaskOutcome) + Send + Sync>
    };
    let report = supervisor::run(
        source,
        BTreeMap::new(),
        SupervisorOptions {
            workers: 2,
            crash_budget: 1,
            connect_timeout: Duration::from_millis(100),
            ..SupervisorOptions::default()
        },
        SupervisorHooks { record: Some(record), ..SupervisorHooks::default() },
        WorkerSource::Pool(Arc::clone(&pool)),
    )
    .unwrap();
    // Every spec is accounted for: all failed explicitly as crashes.
    let completed = completed.lock().unwrap();
    assert_eq!(report.completed, 3);
    assert_eq!(completed.len(), 3);
    assert!(completed.iter().all(|o| !o.succeeded()));
    assert!(
        completed.iter().all(|o| {
            o.failure.as_ref().is_some_and(|f| f.kind == FailureKind::Crash)
        }),
        "leaseless slots retire and fail leftover work explicitly"
    );
}

// ---- experiment-capability routing (protocol v5) ------------------------

/// A matrix mixing the built-in `echo` and §3 `grid` experiments via the
/// reserved `exp` row parameter: 2 echo tasks + 2 grid tasks (the grid
/// rows use the fast `toy` dataset so CV stays cheap).
fn mixed_matrix() -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("exp", vec![pv_str("echo"), pv_str("grid")])
        .param("dataset", vec![pv_str("toy")])
        .param("feature_engineering", vec![pv_str("DummyImputer")])
        .param("preprocessing", vec![pv_str("DummyPreprocessor")])
        .param("model", vec![pv_str("SVC"), pv_str("DecisionTree")])
        .setting("n_fold", Json::int(2))
        .setting("data_seed", Json::int(0))
        .build()
        .unwrap()
}

/// Spawns a standing worker restricted to a subset of the built-in
/// registry's experiments — exactly what `memento serve --exps` builds.
/// Its v5 `Ready` handshake advertises only these names.
fn spawn_subset_worker(
    pool: &Arc<WorkerPool>,
    exps: &[&str],
) -> JoinHandle<Result<RemoteServeReport, MementoError>> {
    let endpoint = pool.endpoint().clone();
    let names: Vec<String> = exps.iter().map(|s| s.to_string()).collect();
    std::thread::spawn(move || {
        let registry = Registry::builtin(None).subset(&names).expect("known names");
        serve_remote(
            Arc::new(registry),
            &endpoint,
            RemoteWorkerOptions {
                token: Some(TOKEN.to_string()),
                max_connections: Some(1),
                give_up_after: Some(Duration::from_secs(1)),
                quiet: true,
                ..RemoteWorkerOptions::default()
            },
        )
    })
}

/// The registry-refactor acceptance test: one run mixing `echo` and the
/// §3 `grid` over TCP-remote with two single-capability workers. The
/// supervisor dispatches each named task only to the worker that
/// registered it (each worker's served-attempt count equals exactly its
/// experiment's task count), accounting is exactly-once, and task
/// identity matches the thread backend.
#[test]
fn mixed_experiment_run_routes_by_capability_over_tcp() {
    let td = TempDir::new("remote-mixed").unwrap();
    let m = mixed_matrix();

    // Thread-backend reference run: named-task identity must be
    // backend-independent.
    let reference = Memento::with_registry(Registry::builtin(None))
        .workers(2)
        .run(&m)
        .unwrap();

    let pool = tcp_pool();
    let w_echo = spawn_subset_worker(&pool, &["echo"]);
    let w_grid = spawn_subset_worker(&pool, &["grid"]);
    let jpath = td.join("mixed.jsonl");
    let results = Memento::with_registry(Registry::builtin(None))
        .with_worker_pool(Arc::clone(&pool))
        .remote_workers("unused: pool owns the listener", 2)
        .with_journal(&jpath)
        .run(&m)
        .unwrap();
    pool.shutdown();
    let re = w_echo.join().unwrap().unwrap();
    let rg = w_grid.join().unwrap().unwrap();

    assert_eq!(results.len(), 4);
    assert_eq!(results.n_failed(), 0);
    // Capable-only dispatch: a mis-routed task would bounce (Unsupported
    // -> re-route) and inflate one of these counts.
    assert_eq!(re.tasks, 2, "echo worker served exactly the echo tasks");
    assert_eq!(rg.tasks, 2, "grid worker served exactly the grid tasks");

    for o in results.iter() {
        let value = o.value.as_ref().expect("all tasks succeed");
        match o.spec.get("exp").and_then(|v| v.as_str()) {
            Some("echo") => assert!(value.get("hash").is_some(), "echo returns params+hash"),
            Some("grid") => assert!(value.get("accuracy").is_some(), "grid returns CV metrics"),
            other => panic!("unexpected exp {other:?}"),
        }
    }
    for (t, r) in reference.iter().zip(results.iter()) {
        assert_eq!(t.id, r.id, "task identity must be backend-independent");
        assert_eq!(t.value, r.value);
    }
    let summary = Journal::summarize(&jpath).unwrap();
    assert_eq!(summary.started, 4, "{summary:?}");
    assert_eq!(summary.succeeded, 4, "{summary:?}");
    assert_eq!(summary.failed_attempts, 0, "{summary:?}");
    assert_eq!(summary.timeouts, 0, "{summary:?}");
    assert_eq!(summary.restored, 0, "{summary:?}");
}

/// Named tasks whose experiment no live worker registers fail explicitly
/// — typed `unknown-experiment`, reason journaled — instead of hanging
/// the run or burning the crash budget; tasks the worker does register
/// still succeed, and the incapable worker never receives out-of-set
/// tasks.
#[test]
fn unservable_named_tasks_fail_explicitly_with_journaled_reason() {
    let td = TempDir::new("remote-unservable").unwrap();
    let pool = tcp_pool();
    let w_echo = spawn_subset_worker(&pool, &["echo"]);
    let jpath = td.join("unservable.jsonl");
    let results = Memento::with_registry(Registry::builtin(None))
        .with_worker_pool(Arc::clone(&pool))
        .remote_workers("unused: pool owns the listener", 1)
        .with_journal(&jpath)
        .run(&mixed_matrix())
        .unwrap();
    pool.shutdown();
    let re = w_echo.join().unwrap().unwrap();

    assert_eq!(results.len(), 4);
    assert_eq!(results.n_failed(), 2, "the grid-named tasks are unservable");
    assert_eq!(re.tasks, 2, "the subset worker only ever saw echo tasks");
    for o in results.iter() {
        match o.spec.get("exp").and_then(|v| v.as_str()) {
            Some("echo") => assert!(o.failure.is_none(), "echo tasks still succeed"),
            Some("grid") => {
                let f = o.failure.as_ref().expect("grid tasks fail explicitly");
                assert_eq!(f.kind, FailureKind::UnknownExperiment);
                assert!(
                    f.message.contains("no live worker registers experiment 'grid'"),
                    "{}",
                    f.message
                );
            }
            other => panic!("unexpected exp {other:?}"),
        }
    }
    // The reason lands in the journal; the unservable tasks fail from
    // the queue without ever starting, so accounting stays exactly-once.
    let text = std::fs::read_to_string(&jpath).unwrap();
    assert!(text.contains("no live worker registers experiment 'grid'"), "{text}");
    let summary = Journal::summarize(&jpath).unwrap();
    assert_eq!(summary.started, 2, "{summary:?}");
    assert_eq!(summary.succeeded, 2, "{summary:?}");
    assert_eq!(summary.failed_attempts, 2, "{summary:?}");
}
