//! Runtime integration: the full AOT bridge — JAX/Pallas-lowered HLO text →
//! PJRT compile → execute from Rust — with real numerics checks.
//!
//! Requires `make artifacts` (the Makefile runs it before `cargo test`).

use memento::ml::data::Dataset;
use memento::ml::dataset::toy;
use memento::ml::impute::{SimpleImputer, Transformer};
use memento::ml::metrics::accuracy;
use memento::ml::scale::StandardScaler;
use memento::ml::split::train_test_indices;
use memento::ml::tree::Classifier;
use memento::runtime::artifact::{shared_store, ArtifactStore};
use memento::runtime::mlp::{MlpModel, MlpParams};
use memento::runtime::tensor::Tensor;
use memento::util::rng::Rng;

fn artifacts_available() -> bool {
    ArtifactStore::default_dir().join("manifest.json").exists()
}

#[test]
fn manifest_and_executables_load() {
    if !artifacts_available() {
        panic!("artifacts missing — run `make artifacts` before cargo test");
    }
    let store = shared_store().unwrap();
    let mut names = store.names();
    names.sort();
    assert_eq!(names, vec!["mlp_predict", "mlp_train_step"]);
    assert_eq!(store.meta.batch, 128);
    assert_eq!(store.meta.features, 64);
    assert_eq!(store.meta.classes, 10);
    // compile both
    store.executable("mlp_predict").unwrap();
    store.executable("mlp_train_step").unwrap();
    assert_eq!(store.compiled_count(), 2);
    // compile is cached (same Arc)
    let a = store.executable("mlp_predict").unwrap();
    let b = store.executable("mlp_predict").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

#[test]
fn predict_executes_with_correct_shapes() {
    let store = shared_store().unwrap();
    let m = store.meta;
    let exe = store.executable("mlp_predict").unwrap();
    let w1 = Tensor::zeros(vec![m.features, m.hidden]);
    let b1 = Tensor::zeros(vec![m.hidden]);
    let w2 = Tensor::zeros(vec![m.hidden, m.classes]);
    let b2 = Tensor::zeros(vec![m.classes]);
    let x = Tensor::zeros(vec![m.batch, m.features]);
    let mask = Tensor::new(vec![m.classes], {
        let mut v = vec![0f32; m.classes];
        v[0] = 1.0;
        v[1] = 1.0;
        v
    });
    let out = exe.run(&[&w1, &b1, &w2, &b2, &x, &mask]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![m.batch, m.classes]);
    // masked logits: classes >= 2 get -1e9
    for row in 0..m.batch {
        assert_eq!(out[0].at2(row, 0), 0.0);
        assert!(out[0].at2(row, 5) < -1e8);
    }
}

#[test]
fn train_step_loss_matches_masked_uniform_and_decreases() {
    let store = shared_store().unwrap();
    let m = store.meta;
    let step = store.executable("mlp_train_step").unwrap();

    // Random first layer (so gradients flow through the ReLU) but zero
    // output layer → logits are exactly 0 → uniform over the 3 valid
    // classes → first loss = ln 3 exactly.
    let mut rng = Rng::new(42);
    let he = (2.0 / m.features as f64).sqrt();
    let w1_data: Vec<f32> = (0..m.features * m.hidden)
        .map(|_| (rng.normal() * he) as f32)
        .collect();
    let mut w1 = Tensor::new(vec![m.features, m.hidden], w1_data);
    let mut b1 = Tensor::zeros(vec![m.hidden]);
    let mut w2 = Tensor::zeros(vec![m.hidden, m.classes]);
    let mut b2 = Tensor::zeros(vec![m.classes]);

    // Separable batch: class = sign structure on feature 0..2.
    let mut x = vec![0f32; m.batch * m.features];
    let mut y = vec![0f32; m.batch * m.classes];
    for i in 0..m.batch {
        let class = i % 3;
        for f in 0..8 {
            x[i * m.features + f] =
                (if f == class { 3.0 } else { 0.0 }) + rng.normal() as f32 * 0.1;
        }
        y[i * m.classes + class] = 1.0;
    }
    let x = Tensor::new(vec![m.batch, m.features], x);
    let y = Tensor::new(vec![m.batch, m.classes], y);
    let mask = Tensor::new(vec![m.classes], {
        let mut v = vec![0f32; m.classes];
        v[..3].fill(1.0);
        v
    });
    let lr = Tensor::scalar(0.5);

    let mut losses = Vec::new();
    for _ in 0..25 {
        let out = step.run(&[&w1, &b1, &w2, &b2, &x, &y, &mask, &lr]).unwrap();
        let mut it = out.into_iter();
        w1 = it.next().unwrap();
        b1 = it.next().unwrap();
        w2 = it.next().unwrap();
        b2 = it.next().unwrap();
        losses.push(it.next().unwrap().data[0]);
    }
    let ln3 = 3f32.ln();
    assert!(
        (losses[0] - ln3).abs() < 1e-3,
        "first loss {} != ln3 {}",
        losses[0],
        ln3
    );
    assert!(
        losses[24] < losses[0] * 0.5,
        "loss did not halve: {:?}",
        &losses[..3]
    );
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn mlp_classifier_end_to_end_on_toy_data() {
    let store = shared_store().unwrap();
    let mut ds = toy(3);
    let mut imp = SimpleImputer::default();
    imp.fit_transform(&mut ds);
    let mut sc = StandardScaler::default();
    sc.fit_transform(&mut ds);

    let mut rng = Rng::new(7);
    let (tr, te) = train_test_indices(&ds, 0.3, &mut rng);
    let train = ds.subset(&tr);
    let test = ds.subset(&te);

    let mut mlp = MlpModel::new(store, MlpParams { epochs: 40, lr: 0.2 });
    let history = mlp.fit_with_history(&train, &mut rng).unwrap();
    assert!(history.len() == 40);
    assert!(
        history[39] < history[0],
        "loss history not decreasing: {history:?}"
    );
    let acc = accuracy(&test.y, &mlp.predict(&test));
    assert!(acc > 0.8, "MLP test accuracy {acc}");
}

#[test]
fn mlp_rejects_too_many_classes() {
    let store = shared_store().unwrap();
    // 11 classes > artifact's 10
    let n = 22;
    let ds = Dataset::new(
        "wide",
        vec![0.0; n * 4],
        n,
        4,
        (0..n).map(|i| i % 11).collect(),
        11,
    );
    let mut mlp = MlpModel::new(store, MlpParams::default());
    let err = mlp.fit_with_history(&ds, &mut Rng::new(0)).unwrap_err();
    assert!(err.to_string().contains("classes"), "{err}");
}

#[test]
fn mlp_handles_batch_remainder_and_small_datasets() {
    let store = shared_store().unwrap();
    // 50 rows < batch 128: single padded batch.
    let mut ds = toy(9);
    let rows: Vec<usize> = (0..50).collect();
    let mut small = ds.subset(&rows);
    SimpleImputer::default().fit_transform(&mut small);
    let mut mlp = MlpModel::new(store, MlpParams { epochs: 10, lr: 0.2 });
    let mut rng = Rng::new(1);
    mlp.fit_with_history(&small, &mut rng).unwrap();
    let preds = mlp.try_predict(&small).unwrap();
    assert_eq!(preds.len(), 50);
    assert!(preds.iter().all(|&p| p < small.n_classes), "mask honored");
    let _ = &mut ds;
}

#[test]
fn concurrent_mlp_tasks_share_the_store() {
    // The §3 grid runs MLP tasks on several workers at once; the shared
    // executable must be safe under concurrent use.
    let store = shared_store().unwrap();
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let store = std::sync::Arc::clone(&store);
            std::thread::spawn(move || {
                let mut ds = toy(100 + t);
                SimpleImputer::default().fit_transform(&mut ds);
                let mut mlp = MlpModel::new(store, MlpParams { epochs: 5, lr: 0.1 });
                let mut rng = Rng::new(t);
                mlp.fit_with_history(&ds, &mut rng).unwrap();
                let preds = mlp.try_predict(&ds).unwrap();
                accuracy(&ds.y, &preds)
            })
        })
        .collect();
    for h in handles {
        let acc = h.join().unwrap();
        assert!(acc > 0.4, "concurrent MLP accuracy {acc}");
    }
}
