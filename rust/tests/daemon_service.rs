//! Integration tests for the multi-tenant daemon
//! ([`memento::daemon`]): concurrent run submission over loopback TCP,
//! fair-share scheduling onto one shared worker pool, cross-run store
//! dedup, token auth, detach/attach replay, and the deterministic
//! drain-shutdown / restart-resume cycle.
//!
//! Workers are in-process threads running
//! [`memento::ipc::worker::serve_remote`] against the daemon's worker
//! endpoint — the exact `memento serve` code path. They re-register
//! after every task attempt (`tasks_per_connection: 1`), so the pool's
//! round-robin lease grants interleave concurrent runs at task
//! granularity. Every worker is bounded by `give_up_after`, so threads
//! always join once the daemon's pool shuts down.

#![cfg(unix)]

use memento::coordinator::journal::Journal;
use memento::daemon::{Daemon, DaemonClient, DaemonOptions, RunHandle, SubmitOptions};
use memento::ipc::transport::{Endpoint, Transport};
use memento::ipc::worker::{serve_remote, RemoteServeReport, RemoteWorkerOptions};
use memento::prelude::*;
use memento::util::fs::TempDir;
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const TOKEN: &str = "daemon-test-token";

/// Gate for the quota test's deliberately-stuck task: a task with
/// `block=1` spins until the test releases it.
static RELEASE: AtomicBool = AtomicBool::new(false);

/// The experiment function shared by the daemon (launch side) and every
/// worker. Task identity hashes params + version, so overlapping grids
/// submitted by different tenants produce identical task ids — the
/// cross-run dedup under test.
fn exp(ctx: &TaskContext) -> Result<Json, MementoError> {
    if ctx.param_i64("block").unwrap_or(0) == 1 {
        while !RELEASE.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    let ms = ctx.param_i64("ms").unwrap_or(0);
    if ms > 0 {
        std::thread::sleep(Duration::from_millis(ms as u64));
    }
    let i = ctx.param_i64("i")?;
    Ok(Json::int(i * 10))
}

/// `i` in `lo..hi`, each task sleeping `ms` (shared across tenants so
/// overlapping ranges share task ids).
fn grid(lo: i64, hi: i64, ms: i64) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (lo..hi).map(pv_int).collect())
        .param("ms", vec![pv_int(ms)])
        .build()
        .unwrap()
}

fn start_daemon(root: &Path, max_in_flight: usize) -> Daemon {
    let mut options = DaemonOptions::new(root);
    options.token = Some(TOKEN.to_string());
    options.max_in_flight = max_in_flight;
    options.workers_per_run = 2;
    Daemon::start(
        Registry::solo(Arc::new(exp)),
        options,
        &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
        &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
    )
    .unwrap()
}

/// A standing worker against the daemon's worker endpoint. One task per
/// connection (so lease grants round-robin between runs at task
/// granularity); exits once the pool has been gone for 2 seconds.
fn spawn_worker(
    endpoint: &Endpoint,
) -> JoinHandle<Result<RemoteServeReport, MementoError>> {
    let endpoint = endpoint.clone();
    std::thread::spawn(move || {
        serve_remote(
            Arc::new(Registry::solo(Arc::new(exp))),
            &endpoint,
            RemoteWorkerOptions {
                token: Some(TOKEN.to_string()),
                tasks_per_connection: Some(1),
                give_up_after: Some(Duration::from_secs(2)),
                quiet: true,
                ..RemoteWorkerOptions::default()
            },
        )
    })
}

fn client(endpoint: &Endpoint) -> DaemonClient {
    DaemonClient::new(endpoint.clone(), Some(TOKEN.to_string()))
}

fn submit_opts(tenant: &str, label: &str) -> SubmitOptions {
    SubmitOptions {
        tenant: tenant.to_string(),
        label: Some(label.to_string()),
        ..SubmitOptions::default()
    }
}

/// Drains a run's event stream to the end.
fn collect_events(mut handle: RunHandle) -> Vec<Json> {
    let mut out = Vec::new();
    while let Some(ev) = handle.next_event().unwrap() {
        out.push(ev);
    }
    out
}

fn kind(ev: &Json) -> &str {
    ev.get("event").and_then(|j| j.as_str()).unwrap_or("")
}

fn finished(events: &[Json]) -> Vec<&Json> {
    events.iter().filter(|e| kind(e) == "task_finished").collect()
}

fn run_complete(events: &[Json]) -> &Json {
    events
        .iter()
        .find(|e| kind(e) == "run_complete")
        .expect("stream must end with run_complete")
}

fn int(ev: &Json, field: &str) -> i64 {
    ev.get(field).and_then(|j| j.as_i64()).unwrap_or(-1)
}

/// Polls `f` for up to `secs` seconds.
fn wait_until(secs: f64, mut f: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + Duration::from_secs_f64(secs);
    while Instant::now() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    false
}

/// The phase of `run_id` according to the daemon's status document.
fn phase_of(daemon: &Daemon, run_id: &str) -> String {
    let status = daemon.status();
    status
        .get("runs")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .find(|r| r.get("run_id").and_then(Json::as_str) == Some(run_id))
        .and_then(|r| r.get("phase").and_then(Json::as_str))
        .map(str::to_string)
        .unwrap_or_else(|| "absent".to_string())
}

/// The headline multi-client test: three tenants submit overlapping
/// grids concurrently against one daemon backed by a two-worker TCP
/// pool. Every run completes (no starvation under round-robin leases),
/// per-run journal accounting is exactly-once, and across the fleet
/// every *distinct* task executes exactly once — overlapping cells are
/// restored from the shared store, and identical params yield identical
/// task ids across tenants.
#[test]
fn multi_tenant_submissions_share_the_store_and_account_exactly_once() {
    let td = TempDir::new("daemon-multi").unwrap();
    let daemon = start_daemon(&td.join("root"), 1);
    let w1 = spawn_worker(&daemon.worker_endpoint());
    let w2 = spawn_worker(&daemon.worker_endpoint());
    let endpoint = daemon.endpoint().clone();

    // alice 0..6, bob 3..9, cara 0..4: union 0..9 = 9 distinct cells of
    // 16 submitted.
    let tenants: [(&str, i64, i64); 3] = [("alice", 0, 6), ("bob", 3, 9), ("cara", 0, 4)];
    let clients: Vec<_> = tenants
        .map(|(tenant, lo, hi)| {
            let endpoint = endpoint.clone();
            std::thread::spawn(move || {
                let handle = client(&endpoint)
                    .submit(&grid(lo, hi, 20), &submit_opts(tenant, "g1"))
                    .unwrap();
                let run_id = handle.run_id().to_string();
                (run_id, collect_events(handle))
            })
        })
        .into_iter()
        .collect();
    let runs: Vec<(String, Vec<Json>)> = clients.into_iter().map(|h| h.join().unwrap()).collect();

    let mut succeeded_total = 0;
    let mut restored_total = 0;
    let mut ids_by_i: Vec<BTreeMap<i64, String>> = Vec::new();
    for ((tenant, lo, hi), (run_id, events)) in tenants.iter().zip(&runs) {
        let n = (hi - lo) as usize;
        assert_eq!(run_id, &format!("{tenant}/g1"));

        let complete = run_complete(events);
        assert_eq!(int(complete, "total"), n as i64, "{tenant}: {complete}");
        assert_eq!(int(complete, "failed"), 0, "{tenant}");
        assert_eq!(complete.get("cancelled").and_then(|j| j.as_bool()), Some(false));

        let done = finished(events);
        assert_eq!(done.len(), n, "{tenant}: one terminal event per task");
        let distinct: BTreeSet<&str> =
            done.iter().filter_map(|e| e.get("id").and_then(|j| j.as_str())).collect();
        assert_eq!(distinct.len(), n, "{tenant}: terminal events are per-task unique");
        ids_by_i.push(
            done.iter()
                .map(|e| {
                    let i = e
                        .get("params")
                        .and_then(|p| p.get("i"))
                        .and_then(|j| j.as_i64())
                        .unwrap();
                    let id = e.get("id").and_then(|j| j.as_str()).unwrap().to_string();
                    (i, id)
                })
                .collect(),
        );

        // Per-run exactly-once journal accounting: every cell either
        // executed here (succeeded) or restored from the shared store.
        let jpath = td.join("root").join("runs").join(tenant).join("g1").join("journal.jsonl");
        let summary = Journal::summarize(&jpath).unwrap();
        assert_eq!(summary.succeeded + summary.restored, n, "{tenant}: {summary:?}");
        assert_eq!(summary.failed_attempts, 0, "{tenant}: {summary:?}");
        assert_eq!(summary.timeouts, 0, "{tenant}: {summary:?}");
        succeeded_total += summary.succeeded;
        restored_total += summary.restored;
    }

    // Fleet-wide dedup: 9 distinct cells executed exactly once, the 7
    // overlapping submissions restored — never re-executed.
    assert_eq!(succeeded_total, 9, "every distinct cell executes exactly once");
    assert_eq!(restored_total, 7, "every overlapping cell restores from the store");

    // Task identity is tenant-independent: overlapping `i` values hash to
    // the same id in every run that contains them.
    for a in 0..ids_by_i.len() {
        for b in a + 1..ids_by_i.len() {
            for (i, id) in &ids_by_i[a] {
                if let Some(other) = ids_by_i[b].get(i) {
                    assert_eq!(id, other, "i={i} must have one identity across tenants");
                }
            }
        }
    }

    // The shared store registered all three tenant-labelled runs.
    let status = daemon.status();
    assert_eq!(
        status.get("store").and_then(|s| s.get("runs")).and_then(|j| j.as_i64()),
        Some(3),
        "{status}"
    );

    daemon.shutdown();
    daemon.wait();
    w1.join().unwrap().unwrap();
    w2.join().unwrap().unwrap();
}

/// A client presenting the wrong token (or none) is refused before any
/// daemon state is revealed: the rejection names neither runs, tenants,
/// nor registered experiments, and attach is refused identically even
/// for a run id that exists.
#[test]
fn bad_token_is_rejected_before_any_state_is_revealed() {
    let td = TempDir::new("daemon-auth").unwrap();
    let daemon = start_daemon(&td.join("root"), 2);
    // Seed a real run id so a leaky attach would have something to leak.
    // No workers: the run just sits running; auth must not depend on it.
    let good = client(daemon.endpoint());
    let seeded = good.submit(&grid(0, 2, 0), &submit_opts("alice", "secret-run")).unwrap();
    let seeded_id = seeded.run_id().to_string();
    seeded.detach();

    for token in [Some("wrong-token".to_string()), None] {
        let bad = DaemonClient::new(daemon.endpoint().clone(), token);
        let err = bad.submit(&grid(0, 2, 0), &submit_opts("alice", "x")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("rejected"), "typed rejection, got: {msg}");
        let attach_err = bad.attach(&seeded_id).unwrap_err().to_string();
        let status_err = bad.status().unwrap_err().to_string();
        for msg in [&msg, &attach_err, &status_err] {
            assert!(
                !msg.contains("secret-run") && !msg.contains("alice"),
                "rejection must not leak daemon state: {msg}"
            );
        }
    }
    // An authenticated status still works afterwards — the refusals left
    // the daemon healthy.
    assert!(good.status().is_ok());
    daemon.shutdown();
    daemon.wait();
}

/// A capability-mismatched submission — an `--exp` name the daemon's
/// registry does not contain — fails with a typed reason at submit time
/// and never occupies a queue slot: a well-formed submission right after
/// it runs to completion.
#[test]
fn unknown_experiment_fails_typed_without_wedging_the_queue() {
    let td = TempDir::new("daemon-unknown-exp").unwrap();
    let daemon = start_daemon(&td.join("root"), 2);
    let worker = spawn_worker(&daemon.worker_endpoint());
    let c = client(daemon.endpoint());

    let mut opts = submit_opts("alice", "bad");
    opts.exp = Some("nope".to_string());
    let err = c.submit(&grid(0, 2, 0), &opts).unwrap_err().to_string();
    assert!(err.contains("unknown experiment"), "typed reason, got: {err}");
    assert!(err.contains("nope"), "{err}");

    // The queue is untouched: a valid submission completes normally.
    let events = collect_events(c.submit(&grid(0, 3, 0), &submit_opts("alice", "good")).unwrap());
    let complete = run_complete(&events);
    assert_eq!(int(complete, "total"), 3);
    assert_eq!(int(complete, "failed"), 0);
    assert_eq!(
        daemon.status().get("queue").and_then(|q| q.get("depth")).and_then(|j| j.as_i64()),
        Some(0)
    );

    daemon.shutdown();
    daemon.wait();
    worker.join().unwrap().unwrap();
}

/// Re-submitting an already-used run id (same tenant + label) is
/// rejected without touching the original run's state: the original
/// keeps its pending durability record and event channel, completes
/// normally, and replays in full — both while it is still live and
/// after it has finished (when the id is recognized from its on-disk
/// event record).
#[test]
fn duplicate_run_id_resubmission_never_clobbers_the_original() {
    let td = TempDir::new("daemon-dup").unwrap();
    let root = td.join("root");
    let daemon = start_daemon(&root, 2);
    let c = client(daemon.endpoint());

    // No workers yet: the original sits deterministically mid-run (its
    // tasks cannot execute) while the duplicate arrives.
    let orig = c.submit(&grid(0, 4, 0), &submit_opts("alice", "dup")).unwrap();
    let run_id = orig.run_id().to_string();
    assert!(wait_until(20.0, || phase_of(&daemon, &run_id) == "running"));

    let err = c.submit(&grid(0, 4, 0), &submit_opts("alice", "dup")).unwrap_err().to_string();
    assert!(err.contains("already submitted"), "typed duplicate rejection, got: {err}");
    // The rejection left the original's durability record in place.
    let pending = memento::util::fs::list_files_with_ext(&root.join("pending"), "json").unwrap();
    assert_eq!(pending.len(), 1, "original pending file intact: {pending:?}");

    // Workers arrive; the original completes with full accounting — its
    // channel and submission were never replaced or deleted.
    let worker = spawn_worker(&daemon.worker_endpoint());
    let events = collect_events(orig);
    assert_eq!(finished(&events).len(), 4);
    assert_eq!(int(run_complete(&events), "failed"), 0);

    // Post-completion duplicate: still rejected (the id's event record
    // exists), and attach still replays the original's terminal set.
    let err = c.submit(&grid(0, 4, 0), &submit_opts("alice", "dup")).unwrap_err().to_string();
    assert!(err.contains("already submitted"), "{err}");
    let replay = collect_events(c.attach(&run_id).unwrap());
    assert_eq!(finished(&replay).len(), 4, "replay is the original's, untouched");
    assert_eq!(int(run_complete(&replay), "total"), 4);

    daemon.shutdown();
    daemon.wait();
    worker.join().unwrap().unwrap();
}

/// Path-shaped tenants, labels, and attach run ids are rejected before
/// any filesystem access: a traversal-shaped attach cannot read files
/// outside the daemon root, and a traversal-shaped submission cannot
/// create run state outside it.
#[test]
fn path_shaped_identifiers_are_rejected_before_filesystem_access() {
    let td = TempDir::new("daemon-traverse").unwrap();
    let root = td.join("root");
    let daemon = start_daemon(&root, 2);
    let c = client(daemon.endpoint());

    // A file a traversal-shaped attach (`../secret` resolves run_dir to
    // `<root>/runs/../secret`) would otherwise read and stream back.
    let secret_dir = root.join("secret");
    std::fs::create_dir_all(&secret_dir).unwrap();
    std::fs::write(secret_dir.join("events.jsonl"), "{\"event\":\"leaked\"}\n").unwrap();

    for tenant in ["", "a/b", "..", ".", "a:b", "a\\b"] {
        let err = c.submit(&grid(0, 1, 0), &submit_opts(tenant, "x")).unwrap_err().to_string();
        assert!(err.contains("invalid tenant"), "tenant {tenant:?}: {err}");
    }
    for label in ["", "b/c", "..", "...", "x:y"] {
        let err = c.submit(&grid(0, 1, 0), &submit_opts("alice", label)).unwrap_err().to_string();
        assert!(err.contains("invalid label"), "label {label:?}: {err}");
    }
    for run_id in ["../secret", "alice/../../secret", "alice/..", "/etc/passwd", "alice"] {
        let err = c.attach(run_id).unwrap_err().to_string();
        assert!(err.contains("unknown run id"), "attach {run_id:?}: {err}");
        assert!(!err.contains("leaked"), "attach {run_id:?} must not read outside root");
    }

    // No rejected submission left any state behind.
    let pending = memento::util::fs::list_files_with_ext(&root.join("pending"), "json").unwrap();
    assert!(pending.is_empty(), "rejected submissions must leave no state: {pending:?}");
    assert_eq!(
        daemon.status().get("queue").and_then(|q| q.get("depth")).and_then(|j| j.as_i64()),
        Some(0)
    );

    daemon.shutdown();
    daemon.wait();
}

/// Detaching mid-run must not kill the run, and a later attach replays
/// the complete terminal event set — the events observed before the
/// detach included, with nothing duplicated and nothing missing.
#[test]
fn detach_mid_run_keeps_the_run_alive_and_reattach_replays_everything() {
    let td = TempDir::new("daemon-detach").unwrap();
    let daemon = start_daemon(&td.join("root"), 2);
    let w1 = spawn_worker(&daemon.worker_endpoint());
    let w2 = spawn_worker(&daemon.worker_endpoint());
    let c = client(daemon.endpoint());

    let mut handle = c.submit(&grid(0, 6, 40), &submit_opts("alice", "d1")).unwrap();
    let run_id = handle.run_id().to_string();
    // Read one terminal event, then walk away mid-run.
    loop {
        let ev = handle.next_event().unwrap().expect("run is mid-flight");
        if kind(&ev) == "task_finished" {
            break;
        }
    }
    handle.detach();

    // The run finishes on the daemon with no client attached.
    assert!(
        wait_until(30.0, || phase_of(&daemon, &run_id) == "done"),
        "run must complete while detached (phase: {})",
        phase_of(&daemon, &run_id)
    );

    // Reattach: the full terminal set replays, exactly once per task.
    let events = collect_events(c.attach(&run_id).unwrap());
    let done = finished(&events);
    assert_eq!(done.len(), 6, "replay covers every task, missed ones included");
    let distinct: BTreeSet<&str> =
        done.iter().filter_map(|e| e.get("id").and_then(|j| j.as_str())).collect();
    assert_eq!(distinct.len(), 6, "no duplicates in the replay");
    let complete = run_complete(&events);
    assert_eq!(int(complete, "total"), 6);
    assert_eq!(int(complete, "failed"), 0);

    // Attaching to a run id that never existed is a typed error.
    let err = c.attach("alice/never-submitted").unwrap_err().to_string();
    assert!(err.contains("unknown run id"), "{err}");

    daemon.shutdown();
    daemon.wait();
    w1.join().unwrap().unwrap();
    w2.join().unwrap().unwrap();
}

/// Per-tenant quota and fair-share: with `max_in_flight = 1`, a tenant's
/// second submission waits in the queue while their first runs — but a
/// *different* tenant's later submission skips past it and completes.
/// Deterministic: alice's run blocks on a test-controlled gate, so there
/// is no timing window.
#[test]
fn tenant_quota_queues_second_run_while_other_tenants_proceed() {
    let td = TempDir::new("daemon-quota").unwrap();
    let daemon = start_daemon(&td.join("root"), 1);
    let w1 = spawn_worker(&daemon.worker_endpoint());
    let w2 = spawn_worker(&daemon.worker_endpoint());
    let c = client(daemon.endpoint());

    // a1: a single task that blocks until the test releases it.
    let blocked = ConfigMatrix::builder()
        .param("i", vec![pv_int(1000)])
        .param("block", vec![pv_int(1)])
        .build()
        .unwrap();
    let a1 = c.submit(&blocked, &submit_opts("alice", "a1")).unwrap();
    let a1_id = a1.run_id().to_string();
    assert!(wait_until(20.0, || phase_of(&daemon, &a1_id) == "running"));

    // a2: queued behind the quota while a1 holds alice's slot.
    let a2 = c.submit(&grid(100, 102, 0), &submit_opts("alice", "a2")).unwrap();
    let a2_id = a2.run_id().to_string();

    // b1: a later submission from another tenant completes while a2 is
    // still queued — the scheduler skips over the at-quota tenant.
    let b1_events =
        collect_events(c.submit(&grid(200, 203, 5), &submit_opts("bob", "b1")).unwrap());
    assert_eq!(int(run_complete(&b1_events), "failed"), 0);
    assert_eq!(finished(&b1_events).len(), 3);

    assert_eq!(phase_of(&daemon, &a1_id), "running", "a1 still holds the slot");
    assert_eq!(phase_of(&daemon, &a2_id), "queued", "a2 must wait for alice's quota");
    let status = daemon.status();
    let tenants = status.get("tenants").and_then(Json::as_arr).unwrap_or(&[]);
    assert!(
        tenants.iter().any(|t| {
            t.get("tenant").and_then(|j| j.as_str()) == Some("alice")
                && t.get("in_flight").and_then(|j| j.as_i64()) == Some(1)
        }),
        "{status}"
    );

    // Release the gate: a1 completes, freeing the slot; a2 runs.
    RELEASE.store(true, Ordering::SeqCst);
    assert_eq!(int(run_complete(&collect_events(a1)), "failed"), 0);
    assert_eq!(int(run_complete(&collect_events(a2)), "failed"), 0);

    daemon.shutdown();
    daemon.wait();
    w1.join().unwrap().unwrap();
    w2.join().unwrap().unwrap();
}

/// The deterministic drain cycle: a wire `Shutdown` with one run in
/// flight and another queued. The in-flight run drains (completed
/// attempts persist, the rest are accounted skipped, the trace footer is
/// sealed), the queued run never starts, and both stay pending on disk.
/// A restarted daemon on the same root re-admits both; completed cells
/// restore from the shared store, the rest execute — across both daemon
/// lives every cell runs exactly once (no lost, no duplicated outcomes).
#[test]
fn drain_shutdown_then_restart_resumes_pending_without_rework() {
    let td = TempDir::new("daemon-drain").unwrap();
    let root = td.join("root");

    // ---- first daemon life: drain mid-run --------------------------------
    let daemon = start_daemon(&root, 1);
    let worker = spawn_worker(&daemon.worker_endpoint());
    let c = client(daemon.endpoint());

    let m = grid(0, 8, 50);
    let mut r1 = c.submit(&m, &submit_opts("alice", "r1")).unwrap();
    c.submit(&m, &submit_opts("alice", "r2")).unwrap().detach();
    // Same grid twice: whatever r1 doesn't finish before the drain, the
    // pair still covers each cell exactly once across both lives.
    loop {
        let ev = r1.next_event().unwrap().expect("r1 is mid-flight");
        if kind(&ev) == "task_finished" {
            break;
        }
    }
    assert_eq!(phase_of(&daemon, "alice/r2"), "queued", "quota holds r2 back");

    c.request_shutdown().unwrap();
    // The submit stream observes the drain: r1's terminal run_complete
    // arrives with cancelled=true and its unfinished remainder skipped.
    let r1_events = collect_events(r1);
    let complete1 = run_complete(&r1_events);
    assert_eq!(complete1.get("cancelled").and_then(|j| j.as_bool()), Some(true));
    assert_eq!(int(complete1, "failed"), 0, "drain completes in-flight attempts cleanly");
    assert_eq!(
        int(complete1, "total") + int(complete1, "skipped"),
        8,
        "every cell is accounted: finished or skipped — {complete1}"
    );
    assert!(int(complete1, "skipped") > 0, "the drain arrived mid-run");
    daemon.wait();

    let r1_dir = root.join("runs").join("alice").join("r1");
    let s1 = Journal::summarize(&r1_dir.join("journal.jsonl")).unwrap().succeeded;
    assert!(s1 >= 1, "at least the observed task completed before the drain");
    assert!(s1 < 8, "the drain stopped the run early");

    // The cancelled run sealed its trace footer on the way out.
    let trace = memento::obs::trace::read_trace(
        &r1_dir.join("trace").join(memento::obs::trace::TRACE_FILE),
    )
    .unwrap();
    assert!(trace.footer_spans.is_some(), "drain must seal the trace footer");

    // Both submissions survived as pending files.
    let pending =
        memento::util::fs::list_files_with_ext(&root.join("pending"), "json").unwrap();
    assert_eq!(pending.len(), 2, "cancelled + queued runs stay pending: {pending:?}");
    worker.join().unwrap().unwrap();

    // ---- second daemon life: resume --------------------------------------
    let daemon = start_daemon(&root, 1);
    let worker = spawn_worker(&daemon.worker_endpoint());
    let c = client(daemon.endpoint());

    let r1_events = collect_events(c.attach("alice/r1").unwrap());
    let r2_events = collect_events(c.attach("alice/r2").unwrap());
    let mut fresh = 0;
    for (label, events) in [("r1", &r1_events), ("r2", &r2_events)] {
        let complete = run_complete(events);
        assert_eq!(int(complete, "total"), 8, "{label}: {complete}");
        assert_eq!(int(complete, "failed"), 0, "{label}");
        assert_eq!(complete.get("cancelled").and_then(|j| j.as_bool()), Some(false));
        fresh += finished(events)
            .iter()
            .filter(|e| e.get("from_cache").and_then(|j| j.as_bool()) == Some(false))
            .count();
    }
    // No lost outcomes (the 8 - s1 unfinished cells all executed) and no
    // duplicated outcomes (the s1 finished ones restored, on either run).
    assert_eq!(fresh, 8 - s1, "exactly the unfinished remainder re-executes");

    // r1's journal spans both lives: its cells executed exactly once in
    // total, and the second life restored everything the first finished.
    let summary = Journal::summarize(&r1_dir.join("journal.jsonl")).unwrap();
    assert_eq!(summary.failed_attempts, 0, "{summary:?}");

    // Pending files are consumed once their runs complete un-cancelled.
    assert!(wait_until(10.0, || {
        memento::util::fs::list_files_with_ext(&root.join("pending"), "json")
            .map(|v| v.is_empty())
            .unwrap_or(false)
    }));

    daemon.shutdown();
    daemon.wait();
    worker.join().unwrap().unwrap();
}
