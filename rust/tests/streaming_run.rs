//! Integration tests for the streaming `Run` handle API
//! (`Memento::launch` → `Run::events` → `Run::collect`/`Run::cancel`).
//!
//! The acceptance-criterion tests prove *causally* that a `TaskFinished`
//! event is observable **before** the run completes, on both backends:
//! every task except the first blocks until the test observer has
//! actually received the first task's `TaskFinished` event (via a shared
//! flag for the thread backend, via a filesystem flag for the process
//! backend — workers are separate processes). If events were only
//! delivered after the run finished, these tests would dead-end into
//! their 30-second guard and fail.
//!
//! # How process-backend workers spawn under libtest
//!
//! Same pattern as `ipc_process_backend.rs`: the supervisor re-executes
//! this test binary with `--exact ipc_stream_worker_entry`, which is a
//! no-op in a normal test pass and a worker loop when the worker
//! environment is set.

use memento::prelude::*;
use memento::util::fs::TempDir;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn int_matrix(n: i64) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n).map(pv_int).collect())
        .build()
        .unwrap()
}

// ---- thread backend -----------------------------------------------------

#[test]
fn thread_backend_emits_task_finished_before_run_completes() {
    let release = Arc::new(AtomicBool::new(false));
    let r2 = Arc::clone(&release);
    let mem = Memento::new(move |ctx| {
        let i = ctx.param_i64("i")?;
        if i != 0 {
            // Block until the observer has *received* a TaskFinished
            // event. If events only flowed after run completion this
            // would never release.
            let start = std::time::Instant::now();
            while !r2.load(Ordering::SeqCst) {
                if start.elapsed() > Duration::from_secs(30) {
                    return Err(MementoError::experiment(
                        "no TaskFinished event observed while run in flight",
                    ));
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        Ok(Json::int(i * 10))
    })
    .workers(2);

    let matrix = int_matrix(6);
    let run = mem.launch(&matrix).unwrap();

    let mut saw_finished_live = false;
    let mut finished = 0usize;
    let mut started_ids: Vec<TaskId> = Vec::new();
    let mut summary: Option<RunSummary> = None;
    let mut last_was_complete = false;
    for event in run.events() {
        last_was_complete = false;
        match event {
            RunEvent::TaskStarted { id, .. } => started_ids.push(id),
            RunEvent::TaskFinished(o) => {
                finished += 1;
                if !release.load(Ordering::SeqCst) {
                    // The run is still blocked on the release flag, so
                    // this event provably arrived mid-run.
                    saw_finished_live = true;
                    assert_eq!(o.spec.get("i"), Some(&pv_int(0)), "first finisher is i=0");
                }
                assert!(
                    started_ids.contains(&o.id),
                    "TaskFinished for a task never reported started"
                );
                release.store(true, Ordering::SeqCst);
            }
            RunEvent::RunComplete(s) => {
                summary = Some(s);
                last_was_complete = true;
            }
            _ => {}
        }
    }
    assert!(saw_finished_live, "TaskFinished must be observable mid-run");
    assert!(last_was_complete, "RunComplete is the terminal event");
    assert_eq!(finished, 6);
    let summary = summary.unwrap();
    assert_eq!(summary.total, 6);
    assert_eq!(summary.succeeded, 6);
    assert!(!summary.aborted && !summary.cancelled);

    let results = run.collect().unwrap();
    assert_eq!(results.len(), 6);
    assert_eq!(results.n_failed(), 0);
}

#[test]
fn run_is_equivalent_to_launch_collect() {
    let exp = |ctx: &TaskContext| Ok(Json::int(ctx.param_i64("i")? * 3));
    let matrix = int_matrix(8);
    let blocking = Memento::new(exp).workers(3).run(&matrix).unwrap();
    let streamed = Memento::new(exp)
        .workers(3)
        .launch(&matrix)
        .unwrap()
        .collect()
        .unwrap();
    assert_eq!(blocking.len(), streamed.len());
    for (b, s) in blocking.iter().zip(streamed.iter()) {
        assert_eq!(b.spec, s.spec);
        assert_eq!(b.value, s.value);
        assert_eq!(b.id, s.id);
    }
}

#[test]
fn cancel_stops_mid_flight_and_collect_returns_partial() {
    let mem = Memento::new(|ctx| {
        std::thread::sleep(Duration::from_millis(5));
        Ok(Json::int(ctx.param_i64("i")?))
    })
    .workers(2);
    let matrix = int_matrix(200);
    let run = mem.launch(&matrix).unwrap();
    for event in run.events() {
        if matches!(event, RunEvent::TaskFinished(_)) {
            run.cancel();
            break;
        }
    }
    let results = run.collect().unwrap();
    assert!(!results.is_empty(), "in-flight work is kept");
    assert!(
        results.len() < 200,
        "cancel did not stop the run: {} outcomes",
        results.len()
    );
    assert_eq!(results.n_failed(), 0);
}

#[test]
fn restored_tasks_stream_as_from_cache_events() {
    let td = TempDir::new("stream-cache").unwrap();
    let matrix = int_matrix(5);
    let make = || {
        Memento::new(|ctx| Ok(Json::int(ctx.param_i64("i")?)))
            .workers(2)
            .with_cache_dir(td.join("cache"))
    };
    make().run(&matrix).unwrap();

    // Second run: everything restores from cache; the events still stream.
    let run = make().launch(&matrix).unwrap();
    let mut restored_events = 0usize;
    let mut summary = None;
    for event in run.events() {
        match event {
            RunEvent::TaskFinished(o) => {
                assert!(o.from_cache, "second run must restore, not execute");
                restored_events += 1;
            }
            RunEvent::RunComplete(s) => summary = Some(s),
            _ => {}
        }
    }
    assert_eq!(restored_events, 5);
    let summary = summary.unwrap();
    assert_eq!(summary.from_cache, 5);
    assert_eq!(summary.total, 5);
    let results = run.collect().unwrap();
    assert_eq!(results.n_cached(), 5);
}

#[test]
fn bounded_channel_undrained_run_delivers_every_terminal_event() {
    // A Run left undrained while the run executes: with the default
    // unbounded channel every outcome would buffer; with a 4-slot bounded
    // channel the workers backpressure instead, and once the consumer
    // finally drains it must still see every TaskFinished plus a correct
    // RunSummary carrying the coalesced-drop count.
    let n = 120usize;
    let mem = Memento::new(|ctx| Ok(Json::int(ctx.param_i64("i")?)))
        .workers(2)
        .event_capacity(4);
    let run = mem.launch(&int_matrix(n as i64)).unwrap();
    // Leave the channel untouched while tasks execute against the full
    // buffer.
    std::thread::sleep(Duration::from_millis(100));

    let mut finished = 0usize;
    let mut progress_events = 0usize;
    let mut summary: Option<RunSummary> = None;
    for event in run.events() {
        match event {
            RunEvent::TaskFinished(_) => finished += 1,
            RunEvent::Progress { .. } => progress_events += 1,
            RunEvent::RunComplete(s) => summary = Some(s),
            _ => {}
        }
        // Drain slower than the workers produce so the buffer stays under
        // pressure (keeps intermediate events coalescing).
        std::thread::sleep(Duration::from_millis(1));
    }
    let summary = summary.expect("terminal RunComplete always delivered");
    assert_eq!(finished, n, "every TaskFinished delivered, none dropped");
    assert_eq!(summary.total, n);
    assert_eq!(summary.succeeded, n);
    // Exactly one Progress event is emitted per terminal task plus one at
    // planning-complete; coalescing may drop some, but delivered + counted
    // drops must account for all of them — nothing vanishes silently.
    assert_eq!(
        progress_events + summary.events_coalesced,
        n + 1,
        "progress accounting: {progress_events} delivered + {} coalesced",
        summary.events_coalesced
    );
    let results = run.collect().unwrap();
    assert_eq!(results.len(), n);
    assert_eq!(results.n_failed(), 0);
}

#[test]
fn unbounded_default_reports_zero_coalesced() {
    let mem = Memento::new(|ctx| Ok(Json::int(ctx.param_i64("i")?))).workers(2);
    let run = mem.launch(&int_matrix(20)).unwrap();
    let mut summary = None;
    for event in run.events() {
        if let RunEvent::RunComplete(s) = event {
            summary = Some(s);
        }
    }
    assert_eq!(summary.unwrap().events_coalesced, 0);
    run.collect().unwrap();
}

#[test]
fn progress_events_report_final_totals() {
    let mem = Memento::new(|ctx| Ok(Json::int(ctx.param_i64("i")?))).workers(2);
    let matrix = int_matrix(10);
    let run = mem.launch(&matrix).unwrap();
    let mut last_progress = None;
    for event in run.events() {
        if let RunEvent::Progress { finished, restored, planned, planning_complete, .. } = event {
            last_progress = Some((finished, restored, planned, planning_complete));
        }
    }
    let (finished, restored, planned, planning_complete) =
        last_progress.expect("at least one Progress event");
    assert!(planning_complete);
    assert_eq!(planned, 10);
    assert_eq!(finished + restored, 10);
    run.collect().unwrap();
}

// ---- process backend ----------------------------------------------------

#[cfg(unix)]
mod process_backend {
    use super::*;
    use std::path::Path;

    /// The experiment function served by the worker entry: every task but
    /// i=0 spins until the release file exists on disk (the cross-process
    /// analogue of the thread test's AtomicBool).
    fn exp(ctx: &TaskContext) -> Result<Json, MementoError> {
        let i = ctx.param_i64("i")?;
        if i != 0 {
            let flag = ctx
                .setting("release_file")
                .and_then(|j| j.as_str())
                .ok_or_else(|| MementoError::experiment("release_file setting missing"))?
                .to_string();
            let start = std::time::Instant::now();
            while !Path::new(&flag).exists() {
                if start.elapsed() > Duration::from_secs(30) {
                    return Err(MementoError::experiment(
                        "no TaskFinished event observed while run in flight",
                    ));
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        Ok(Json::int(i * 7))
    }

    /// Worker entry: spawned via `--exact ipc_stream_worker_entry`. A
    /// no-op in a normal test pass.
    #[test]
    fn ipc_stream_worker_entry() {
        if !memento::ipc::worker::active() {
            return;
        }
        memento::ipc::worker::serve(Arc::new(Registry::solo(Arc::new(exp))))
            .expect("worker serve");
        std::process::exit(0);
    }

    /// Experiment for the cancel test: i=0 returns immediately, every
    /// other task sleeps far longer than the whole test budget — only an
    /// interrupted (killed) worker lets the run finish promptly.
    fn exp_cancel(ctx: &TaskContext) -> Result<Json, MementoError> {
        let i = ctx.param_i64("i")?;
        if i != 0 {
            std::thread::sleep(Duration::from_secs(30));
        }
        Ok(Json::int(i))
    }

    /// Worker entry for the cancel test (no-op in a normal pass).
    #[test]
    fn ipc_cancel_worker_entry() {
        if !memento::ipc::worker::active() {
            return;
        }
        memento::ipc::worker::serve(Arc::new(Registry::solo(Arc::new(exp_cancel))))
            .expect("worker serve");
        std::process::exit(0);
    }

    #[test]
    fn cancel_interrupts_in_flight_process_attempt() {
        // Before this fix, Run::cancel() on the process backend let the
        // in-flight attempt run to completion — here a 30s sleep. Cancel
        // must instead shut the busy worker down within heartbeats and
        // journal the interruption.
        let td = TempDir::new("stream-ipc-cancel").unwrap();
        let jpath = td.join("journal.jsonl");
        let matrix = int_matrix(4);
        let mem = Memento::new(exp_cancel)
            .isolate_processes(1, 1)
            .with_journal(&jpath)
            .worker_args(vec![
                "--exact".to_string(),
                "process_backend::ipc_cancel_worker_entry".to_string(),
            ]);
        let started_at = std::time::Instant::now();
        let run = mem.launch(&matrix).unwrap();
        // Cancel only once the second attempt (the 30s sleeper) has
        // provably been dispatched — cancelling earlier would let the run
        // end cleanly without ever having an attempt to interrupt.
        let mut started = 0usize;
        for event in run.events() {
            if let RunEvent::TaskStarted { .. } = event {
                started += 1;
                if started == 2 {
                    run.cancel();
                    break;
                }
            }
        }
        let results = run.collect().unwrap();
        let elapsed = started_at.elapsed();
        assert!(
            elapsed < Duration::from_secs(15),
            "cancel took {elapsed:?} — latency bounded by the attempt, not a heartbeat"
        );
        assert_eq!(results.len(), 1, "only the quick task reached an outcome");
        assert_eq!(results.n_failed(), 0);

        // The interruption is journaled: i=0 succeeded, the in-flight
        // victim has TaskStarted + a failed attempt explaining the cancel.
        let journal = std::fs::read_to_string(&jpath).unwrap();
        assert!(
            journal.contains("interrupted: run cancelled"),
            "journal missing interruption record:\n{journal}"
        );
        let s = memento::coordinator::journal::Journal::summarize(&jpath).unwrap();
        assert_eq!(s.started, 2, "quick task + interrupted attempt");
        assert_eq!(s.succeeded, 1);
        assert!(s.failed_attempts >= 1, "interruption counted as failed attempt");
    }

    #[test]
    fn process_backend_emits_task_finished_before_run_completes() {
        let td = TempDir::new("stream-ipc").unwrap();
        let flag = td.join("release.flag");
        let matrix = ConfigMatrix::builder()
            .param("i", (0..4).map(pv_int).collect())
            .setting("release_file", Json::str(flag.to_string_lossy().to_string()))
            .build()
            .unwrap();
        let mem = Memento::new(exp)
            .isolate_processes(2, 1)
            .worker_args(vec![
                "--exact".to_string(),
                "process_backend::ipc_stream_worker_entry".to_string(),
            ]);
        let run = mem.launch(&matrix).unwrap();

        let mut saw_finished_live = false;
        let mut finished = 0usize;
        let mut summary = None;
        for event in run.events() {
            match event {
                RunEvent::TaskFinished(o) => {
                    finished += 1;
                    if !flag.exists() {
                        saw_finished_live = true;
                        assert_eq!(o.spec.get("i"), Some(&pv_int(0)));
                    }
                    std::fs::write(&flag, b"go").unwrap();
                }
                RunEvent::RunComplete(s) => summary = Some(s),
                _ => {}
            }
        }
        assert!(
            saw_finished_live,
            "process backend must stream TaskFinished mid-run"
        );
        assert_eq!(finished, 4);
        let summary = summary.unwrap();
        assert_eq!(summary.succeeded, 4);

        let results = run.collect().unwrap();
        assert_eq!(results.len(), 4);
        assert_eq!(results.n_failed(), 0);
        let hit = results.find(&[("i", pv_int(2))]).unwrap();
        assert_eq!(hit.value.as_ref().unwrap().as_i64(), Some(14));
    }
}
