//! Integration tests for the cross-run segment-log store
//! ([`memento::store`]): migrating legacy per-run JSON directories into a
//! store and restoring from it identically on every execution backend,
//! plus cross-run query over what the runs recorded.
//!
//! The process-backend tests reuse the worker-entry pattern documented in
//! `tests/ipc_process_backend.rs`: the supervisor re-executes this test
//! binary with `--exact store_worker_entry` and the worker environment
//! set, so the child serves task attempts with this file's experiment
//! function.

use memento::prelude::*;
use memento::store::ResultStore;
use memento::util::fs::TempDir;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Experiment function shared by the supervisor and every worker tier.
/// Task identity hashes params + version, so ids agree across backends
/// and across the legacy-dir and store-backed runs.
fn exp(ctx: &TaskContext) -> Result<Json, MementoError> {
    let i = ctx.param_i64("i")?;
    Ok(Json::obj(vec![
        ("score", Json::Num(i as f64 / 10.0)),
        ("doubled", Json::int(i * 2)),
    ]))
}

/// Worker entry for the process-backend runs; no-op in a normal pass.
#[test]
fn store_worker_entry() {
    #[cfg(unix)]
    if memento::ipc::worker::active() {
        memento::ipc::worker::serve(Arc::new(Registry::solo(Arc::new(exp)))).expect("worker serve");
        std::process::exit(0);
    }
}

fn matrix(n: i64) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n).map(pv_int).collect())
        .build()
        .unwrap()
}

/// Seeds a legacy per-entry-JSON cache directory by running the grid
/// through a dir-backed `ResultCache`, then folds it into a store.
fn migrated_store(td: &TempDir, n: i64) -> (Arc<ResultStore>, ResultSet) {
    let legacy = td.join("legacy-cache");
    let baseline = Memento::new(exp)
        .workers(2)
        .with_cache_dir(&legacy)
        .run(&matrix(n))
        .unwrap();
    let store = ResultStore::open(td.join("store")).unwrap();
    let report = store.migrate_dir(&legacy).unwrap();
    assert_eq!(report.results as i64, n);
    assert_eq!(report.skipped, 0);
    (store, baseline)
}

#[test]
fn migration_roundtrip_restores_identically_on_thread_backend() {
    let td = TempDir::new("store-int-threads").unwrap();
    let (store, baseline) = migrated_store(&td, 12);

    let executions = Arc::new(AtomicUsize::new(0));
    let ex = Arc::clone(&executions);
    let restored = Memento::new(move |ctx| {
        ex.fetch_add(1, Ordering::SeqCst);
        exp(ctx)
    })
    .workers(2)
    .with_store(Arc::clone(&store))
    .run(&matrix(12))
    .unwrap();

    assert_eq!(executions.load(Ordering::SeqCst), 0, "all restored from store");
    assert_eq!(restored.n_cached(), 12);
    assert_eq!(restored.len(), baseline.len());
    for (b, r) in baseline.iter().zip(restored.iter()) {
        assert_eq!(b.id, r.id);
        assert_eq!(b.value, r.value, "i={:?}", b.spec.get("i"));
    }
}

#[cfg(unix)]
#[test]
fn migration_roundtrip_restores_identically_on_process_backend() {
    let td = TempDir::new("store-int-process").unwrap();
    let (store, baseline) = migrated_store(&td, 8);

    let restored = Memento::new(exp)
        .isolate_processes(2, 1)
        .worker_args(vec!["--exact".to_string(), "store_worker_entry".to_string()])
        .with_store(Arc::clone(&store))
        .run(&matrix(8))
        .unwrap();

    assert_eq!(restored.n_cached(), 8, "nothing dispatched to workers");
    for (b, r) in baseline.iter().zip(restored.iter()) {
        assert_eq!(b.id, r.id);
        assert_eq!(b.value, r.value);
    }
}

#[cfg(unix)]
#[test]
fn migration_roundtrip_restores_identically_on_remote_backend() {
    use memento::coordinator::memento::ExpFn;
    use memento::ipc::pool::{PoolOptions, WorkerPool};
    use memento::ipc::transport::Transport;
    use memento::ipc::worker::{serve_remote, RemoteWorkerOptions};
    use std::time::Duration;

    let td = TempDir::new("store-int-remote").unwrap();
    let (store, baseline) = migrated_store(&td, 8);

    let token = "store-int-token";
    let pool = WorkerPool::listen(
        &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
        PoolOptions { token: Some(token.to_string()), ..PoolOptions::default() },
    )
    .unwrap();
    let endpoint = pool.endpoint().clone();
    let worker = std::thread::spawn(move || {
        let exp_fn: Arc<ExpFn> = Arc::new(exp);
        serve_remote(
            Arc::new(Registry::solo(exp_fn)),
            &endpoint,
            RemoteWorkerOptions {
                token: Some(token.to_string()),
                max_connections: Some(1),
                give_up_after: Some(Duration::from_secs(1)),
                quiet: true,
                ..RemoteWorkerOptions::default()
            },
        )
    });

    let restored = Memento::new(exp)
        .with_worker_pool(Arc::clone(&pool))
        .remote_workers("", 2)
        .with_store(Arc::clone(&store))
        .run(&matrix(8))
        .unwrap();
    // Nothing was dispatched, so the worker may never have been leased:
    // drop the pool so its registration loop gives up and the thread joins.
    drop(pool);
    let _ = worker.join().unwrap();

    assert_eq!(restored.n_cached(), 8, "nothing dispatched to workers");
    for (b, r) in baseline.iter().zip(restored.iter()) {
        assert_eq!(b.id, r.id);
        assert_eq!(b.value, r.value);
    }
}

#[test]
fn named_run_results_carry_experiment_provenance() {
    let td = TempDir::new("store-int-exp").unwrap();
    let store = ResultStore::open(td.join("store")).unwrap();
    let registry = Registry::new()
        .register("alpha", "a1", "provenance test experiment", exp)
        .register_default(exp);
    Memento::with_registry(registry)
        .workers(2)
        .exp("alpha")
        .with_store(Arc::clone(&store))
        .run(&matrix(4))
        .unwrap();

    // Every record is stamped top-level with the entry that produced it…
    let rows = store.query(&[], &QueryOptions::default()).unwrap();
    assert_eq!(rows.len(), 4);
    for row in &rows {
        assert_eq!(row.doc.get("exp").and_then(|j| j.as_str()), Some("alpha"));
        assert_eq!(row.doc.get("exp_version").and_then(|j| j.as_str()), Some("a1"));
    }
    // …and the annotated spec lands in params too, so predicates hit it.
    let named =
        store.query(&parse_predicates("exp=alpha").unwrap(), &QueryOptions::default()).unwrap();
    assert_eq!(named.len(), 4);
}

#[test]
fn migration_carries_experiment_stamps() {
    let td = TempDir::new("store-int-exp-mig").unwrap();
    let legacy = td.join("legacy");
    let registry = Registry::new()
        .register("alpha", "a1", "provenance test experiment", exp)
        .register_default(exp);
    Memento::with_registry(registry)
        .workers(2)
        .exp("alpha")
        .with_cache_dir(&legacy)
        .run(&matrix(3))
        .unwrap();

    let store = ResultStore::open(td.join("store")).unwrap();
    let report = store.migrate_dir(&legacy).unwrap();
    assert_eq!(report.results, 3);
    let rows = store.query(&[], &QueryOptions::default()).unwrap();
    assert_eq!(rows.len(), 3);
    for row in &rows {
        assert_eq!(row.doc.get("exp").and_then(|j| j.as_str()), Some("alpha"));
        assert_eq!(row.doc.get("exp_version").and_then(|j| j.as_str()), Some("a1"));
    }
}

#[test]
fn migrated_results_answer_cross_run_queries() {
    let td = TempDir::new("store-int-query").unwrap();
    let (store, _) = migrated_store(&td, 12);

    // Run a second grid straight into the store so the query spans a
    // migrated run and a native one.
    Memento::new(exp)
        .workers(2)
        .with_store(Arc::clone(&store))
        .run(&matrix(16))
        .unwrap();
    assert_eq!(store.stats().live_records, 16, "12 restored + 4 new");

    let preds = parse_predicates("i>=10").unwrap();
    let rows = store.query(&preds, &QueryOptions::default()).unwrap();
    assert_eq!(rows.len(), 6, "i in 10..16");
    for row in &rows {
        let i = row.doc.get("params").and_then(|p| p.get("i")).and_then(|v| v.as_i64());
        assert!(i.is_some_and(|i| i >= 10), "{:?}", row.doc);
        let doubled = row.doc.get("value").and_then(|v| v.get("doubled")).and_then(|v| v.as_i64());
        assert_eq!(doubled, i.map(|i| i * 2));
    }
}
