//! CLI integration: drives the built `memento` binary over real config
//! files — expand counts (E1), run/resume, status, and report.

use memento::util::fs::TempDir;
use std::path::PathBuf;
use std::process::Command;

fn bin() -> PathBuf {
    // target/debug|release/deps/<test> → target/<profile>/memento
    let mut p = std::env::current_exe().unwrap();
    p.pop();
    if p.ends_with("deps") {
        p.pop();
    }
    p.join("memento")
}

fn repo_config(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs").join(name)
}

fn run_cli(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(bin())
        .args(args)
        .output()
        .expect("spawn memento binary");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn expand_reports_paper_counts() {
    let (stdout, stderr, ok) = run_cli(&[
        "expand",
        repo_config("paper_grid.json").to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("raw combinations : 54"), "{stdout}");
    assert!(stdout.contains("excluded         : 9"), "{stdout}");
    assert!(stdout.contains("included tasks   : 45"), "{stdout}");
}

#[test]
fn expand_with_ids_prints_hashes() {
    let (stdout, _, ok) = run_cli(&[
        "expand",
        repo_config("toy_grid.json").to_str().unwrap(),
        "--ids",
    ]);
    assert!(ok);
    // 12-hex-char short ids present
    assert!(
        stdout.lines().filter(|l| l.contains("dataset=toy")).count() >= 8,
        "{stdout}"
    );
}

#[test]
fn run_then_resume_then_status_then_report() {
    let td = TempDir::new("cli-run").unwrap();
    let out_file = td.join("results.json");
    let ckpt = td.join("run");
    let cache = td.join("cache");

    // run
    let (stdout, stderr, ok) = run_cli(&[
        "run",
        repo_config("toy_grid.json").to_str().unwrap(),
        "--workers",
        "4",
        "--quiet",
        "--cache",
        cache.to_str().unwrap(),
        "--checkpoint",
        ckpt.to_str().unwrap(),
        "--out",
        out_file.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}\nstdout: {stdout}");
    assert!(stdout.contains("8 task(s): 8 succeeded"), "{stdout}");
    assert!(out_file.exists());

    // status
    let (stdout, _, ok) = run_cli(&["status", "--checkpoint", ckpt.to_str().unwrap()]);
    assert!(ok);
    assert!(stdout.contains("8/8 completed (0 failed)"), "{stdout}");

    // resume (everything restored)
    let (stdout, stderr, ok) = run_cli(&[
        "resume",
        repo_config("toy_grid.json").to_str().unwrap(),
        "--quiet",
        "--checkpoint",
        ckpt.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("8 from cache"), "{stdout}");

    // report
    let (stdout, _, ok) = run_cli(&[
        "report",
        "--results",
        out_file.to_str().unwrap(),
        "--rows",
        "model",
        "--cols",
        "preprocessing",
    ]);
    assert!(ok);
    assert!(stdout.contains("model\\preprocessing"), "{stdout}");
    assert!(stdout.contains("SVC"), "{stdout}");
}

#[test]
fn run_output_ndjson_streams_one_line_per_outcome() {
    let td = TempDir::new("cli-ndjson").unwrap();
    let (stdout, stderr, ok) = run_cli(&[
        "run",
        repo_config("toy_grid.json").to_str().unwrap(),
        "--workers",
        "2",
        "--quiet",
        "--output",
        "ndjson",
        "--cache",
        td.join("cache").to_str().unwrap(),
        "--checkpoint",
        td.join("run").to_str().unwrap(),
        "--out",
        td.join("results.json").to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}\nstdout: {stdout}");
    // Every stdout line is one parseable JSON event; 8 task_finished
    // lines (one per toy-grid task) plus the terminal run_complete.
    let mut finished = 0usize;
    let mut complete = 0usize;
    for line in stdout.lines().filter(|l| !l.trim().is_empty()) {
        let doc = memento::util::json::parse(line)
            .unwrap_or_else(|e| panic!("non-JSON ndjson line: {e}\n{line}"));
        match doc.get("event").and_then(|j| j.as_str()) {
            Some("task_finished") => {
                finished += 1;
                assert!(doc.get("params").is_some(), "{line}");
                assert_eq!(doc.get("status").and_then(|j| j.as_str()), Some("success"));
            }
            Some("run_complete") => complete += 1,
            other => panic!("unexpected ndjson event {other:?}: {line}"),
        }
    }
    assert_eq!(finished, 8, "{stdout}");
    assert_eq!(complete, 1, "{stdout}");
    // The summary table stays off stdout in ndjson mode.
    assert!(!stdout.contains("task(s):"), "{stdout}");
    assert!(td.join("results.json").exists());
}

#[test]
fn expand_limit_previews_without_full_count() {
    let (stdout, stderr, ok) = run_cli(&[
        "expand",
        repo_config("paper_grid.json").to_str().unwrap(),
        "--limit",
        "5",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("raw combinations : 54"), "{stdout}");
    assert!(stdout.contains("showing first    : 5"), "{stdout}");
    assert_eq!(
        stdout.lines().filter(|l| l.trim_start().starts_with('[')).count(),
        5,
        "{stdout}"
    );
}

#[test]
fn expand_sample_is_unbiased_and_deterministic() {
    // paper_grid has 45 included tasks; --sample 6 must draw 6 of them
    // uniformly over the whole stream (not just the first block, which is
    // --limit's bias) and identically for identical seeds.
    let sample = |seed: &str| {
        let (stdout, stderr, ok) = run_cli(&[
            "expand",
            repo_config("paper_grid.json").to_str().unwrap(),
            "--sample",
            "6",
            "--seed",
            seed,
        ]);
        assert!(ok, "stderr: {stderr}");
        assert!(stdout.contains("included tasks   : 45"), "{stdout}");
        assert!(stdout.contains("sampled          : 6 of 45"), "{stdout}");
        let lines: Vec<String> = stdout
            .lines()
            .filter(|l| l.trim_start().starts_with('['))
            .map(|l| l.to_string())
            .collect();
        assert_eq!(lines.len(), 6, "{stdout}");
        lines
    };
    assert_eq!(sample("7"), sample("7"), "same seed, same preview");
    assert_ne!(sample("7"), sample("8"), "different seed, different preview");
}

#[test]
fn bad_config_fails_cleanly() {
    let td = TempDir::new("cli-bad").unwrap();
    let bad = td.join("bad.json");
    std::fs::write(&bad, r#"{"parameters": {"x": []}}"#).unwrap();
    let (_, stderr, ok) = run_cli(&["expand", bad.to_str().unwrap()]);
    assert!(!ok);
    assert!(stderr.contains("empty domain"), "{stderr}");
}

#[test]
fn unknown_command_and_help() {
    let (_, stderr, ok) = run_cli(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"), "{stderr}");
    let (stdout, _, ok) = run_cli(&["help"]);
    assert!(ok);
    assert!(stdout.contains("USAGE"), "{stdout}");
}

#[test]
fn resume_without_checkpoint_flag_errors() {
    let (_, stderr, ok) = run_cli(&[
        "resume",
        repo_config("toy_grid.json").to_str().unwrap(),
        "--quiet",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--checkpoint"), "{stderr}");
}
