//! Coordinator integration: the full reliability pipeline under realistic
//! (multi-worker, crashy, cache-sharing) conditions.

use memento::config::matrix::ConfigMatrix;
use memento::config::value::{pv_int, pv_str};
use memento::coordinator::cache::ResultCache;
use memento::coordinator::checkpoint::CheckpointStore;
use memento::coordinator::error::MementoError;
use memento::coordinator::memento::Memento;
use memento::coordinator::notify::{MemoryNotificationProvider, Notification};
use memento::coordinator::retry::RetryPolicy;
use memento::util::fs::TempDir;
use memento::util::json::Json;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn matrix(n: usize) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n as i64).map(pv_int).collect())
        .param("side", vec![pv_str("a"), pv_str("b")])
        .build()
        .unwrap()
}

#[test]
fn crash_mid_run_then_resume_completes_everything() {
    // Simulate a hard crash: the first run's experiment function starts
    // failing (as if the process died and tasks were lost), then a resume
    // with healthy code completes the run. The invariant: after resume,
    // every task has exactly one successful outcome, and no completed task
    // from the first run was re-executed.
    let td = TempDir::new("int-crash").unwrap();
    let run_dir = td.join("run");
    let m20 = matrix(10); // 20 tasks

    let first_run_execs = Arc::new(AtomicUsize::new(0));
    let ex = Arc::clone(&first_run_execs);
    let crashing = Memento::new(move |ctx| {
        let n = ex.fetch_add(1, Ordering::SeqCst);
        if n >= 7 {
            return Err(MementoError::experiment("simulated crash"));
        }
        Ok(Json::int(ctx.param_i64("i")?))
    })
    .workers(1)
    .checkpoint_flush_every(1)
    .with_checkpoint_dir(&run_dir);
    let r1 = crashing.run(&m20).unwrap();
    assert_eq!(r1.successes().count(), 7);

    // Resume with healthy code.
    let second_run_execs = Arc::new(AtomicUsize::new(0));
    let ex2 = Arc::clone(&second_run_execs);
    let healthy = Memento::new(move |ctx| {
        ex2.fetch_add(1, Ordering::SeqCst);
        Ok(Json::int(ctx.param_i64("i")?))
    })
    .workers(4)
    .with_checkpoint_dir(&run_dir);
    let r2 = healthy.resume(&m20).unwrap();
    assert_eq!(r2.len(), 20);
    assert_eq!(r2.n_failed(), 0);
    assert_eq!(second_run_execs.load(Ordering::SeqCst), 13);
    assert_eq!(r2.n_cached(), 7);
}

#[test]
fn kill_v_half_written_manifest_is_survivable() {
    // Corrupt the manifest mid-file (as a torn write would) — resume must
    // fail cleanly (storage error), not panic or silently run wrong.
    let td = TempDir::new("int-torn").unwrap();
    let run_dir = td.join("run");
    let m = matrix(2);
    Memento::new(|_| Ok(Json::Null))
        .with_checkpoint_dir(&run_dir)
        .run(&m)
        .unwrap();
    // Truncate the manifest to simulate a torn write *outside* the atomic
    // rename path (e.g. filesystem corruption).
    let manifest = run_dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest).unwrap();
    std::fs::write(&manifest, &text[..text.len() / 2]).unwrap();
    let err = Memento::new(|_| Ok(Json::Null))
        .with_checkpoint_dir(&run_dir)
        .resume(&m)
        .unwrap_err();
    assert!(matches!(err, MementoError::Storage(_)), "{err}");
}

#[test]
fn shared_cache_across_different_matrices() {
    // Two overlapping matrices share a cache: the overlap is computed once.
    let td = TempDir::new("int-shared").unwrap();
    let cache = Arc::new(ResultCache::open(td.join("cache")).unwrap());
    let execs = Arc::new(AtomicUsize::new(0));

    let small = ConfigMatrix::builder()
        .param("i", (0..4i64).map(pv_int).collect())
        .build()
        .unwrap();
    let big = ConfigMatrix::builder()
        .param("i", (0..8i64).map(pv_int).collect())
        .build()
        .unwrap();

    let make = |ex: Arc<AtomicUsize>, cache: Arc<ResultCache>| {
        Memento::new(move |ctx| {
            ex.fetch_add(1, Ordering::SeqCst);
            Ok(Json::int(ctx.param_i64("i")? * 2))
        })
        .with_cache(cache)
    };
    make(Arc::clone(&execs), Arc::clone(&cache)).run(&small).unwrap();
    assert_eq!(execs.load(Ordering::SeqCst), 4);
    let r = make(Arc::clone(&execs), Arc::clone(&cache)).run(&big).unwrap();
    assert_eq!(execs.load(Ordering::SeqCst), 8, "only i=4..8 executed");
    assert_eq!(r.n_cached(), 4);
}

#[test]
fn notifications_fire_in_order_with_failures() {
    let notifier = Arc::new(MemoryNotificationProvider::new());
    let m = matrix(3); // 6 tasks
    let _ = Memento::new(|ctx| {
        if ctx.param_i64("i")? == 1 {
            Err(MementoError::experiment("bad"))
        } else {
            Ok(Json::Null)
        }
    })
    .workers(2)
    .with_shared_notifier(Arc::clone(&notifier) as _)
    .run(&m)
    .unwrap();
    let events = notifier.events();
    assert!(matches!(events[0], Notification::RunStarted { total: 6, .. }));
    assert!(matches!(events.last().unwrap(), Notification::RunFinished { failed: 2, .. }));
    let failures = events
        .iter()
        .filter(|e| matches!(e, Notification::TaskFailed { .. }))
        .count();
    assert_eq!(failures, 2);
}

#[test]
fn retry_with_checkpoint_progress_accumulates_across_attempts() {
    // A k-fold style task checkpoints per-fold progress; attempts resume
    // from the last completed fold instead of starting over.
    let td = TempDir::new("int-folds").unwrap();
    let m = ConfigMatrix::builder()
        .param("only", vec![pv_int(0)])
        .build()
        .unwrap();
    let folds_run = Arc::new(AtomicUsize::new(0));
    let fr = Arc::clone(&folds_run);
    let r = Memento::new(move |ctx| {
        let start = ctx
            .restored()
            .and_then(|j| j.get("folds_done").and_then(|v| v.as_i64()))
            .unwrap_or(0);
        for fold in start..5 {
            fr.fetch_add(1, Ordering::SeqCst);
            ctx.save_progress(Json::obj(vec![("folds_done", Json::int(fold + 1))]));
            // Fail twice partway through.
            if ctx.attempt < 3 && fold == 2 {
                return Err(MementoError::experiment("fold crashed"));
            }
        }
        Ok(Json::int(5))
    })
    .with_retry(RetryPolicy::fixed(3, Duration::ZERO))
    .with_checkpoint_dir(td.join("run"))
    .run(&m)
    .unwrap();
    assert_eq!(r.n_failed(), 0);
    // attempt1: folds 0,1,2 (3); attempt2: folds 2 (1, crashes again at 2? no —
    // restored folds_done=3 after crash at fold 2 saved 3... walk it:
    // a1: folds 0,1,2 run (progress 1,2,3), crash at fold==2 → 3 folds
    // a2: start=3, folds 3,4 run? but crash condition fold==2 never hits → succeeds.
    // Total folds executed: 3 + 2 = 5 (no redundant re-execution).
    assert_eq!(folds_run.load(Ordering::SeqCst), 5, "no fold re-ran");
}

#[test]
fn fail_fast_with_many_workers_terminates_quickly() {
    let m = matrix(50); // 100 tasks
    let execs = Arc::new(AtomicUsize::new(0));
    let ex = Arc::clone(&execs);
    let err = Memento::new(move |_| -> Result<Json, MementoError> {
        ex.fetch_add(1, Ordering::SeqCst);
        std::thread::sleep(Duration::from_millis(1));
        Err(MementoError::experiment("doomed"))
    })
    .workers(4)
    .fail_fast(true)
    .run(&m)
    .unwrap_err();
    assert!(matches!(err, MementoError::Aborted(_)));
    // Far fewer than 100 tasks should have started.
    assert!(
        execs.load(Ordering::SeqCst) < 20,
        "executed {} tasks after abort",
        execs.load(Ordering::SeqCst)
    );
}

#[test]
fn checkpoint_store_exists_detects_runs() {
    let td = TempDir::new("int-exists").unwrap();
    assert!(!CheckpointStore::exists(&td.join("run")));
    Memento::new(|_| Ok(Json::Null))
        .with_checkpoint_dir(td.join("run"))
        .run(&matrix(1))
        .unwrap();
    assert!(CheckpointStore::exists(&td.join("run")));
}

#[test]
fn hundred_workers_thousand_tasks_stress() {
    let m = ConfigMatrix::builder()
        .param("i", (0..1000i64).map(pv_int).collect())
        .build()
        .unwrap();
    let r = Memento::new(|ctx| Ok(Json::int(ctx.param_i64("i")? + 1)))
        .workers(100)
        .run(&m)
        .unwrap();
    assert_eq!(r.len(), 1000);
    assert_eq!(r.n_failed(), 0);
    // spot-check values
    let hit = r.find(&[("i", pv_int(500))]).unwrap();
    assert_eq!(hit.value.as_ref().unwrap().as_i64(), Some(501));
}

// ---- cross-module property tests -----------------------------------------

use memento::testing::prop::check;

#[test]
fn prop_cache_roundtrip_any_json_value() {
    let td = TempDir::new("prop-cache").unwrap();
    let cache = ResultCache::open(td.path()).unwrap();
    check("cache-roundtrip-json", 50, |g| {
        // random JSON-ish value
        fn gen_json(g: &mut memento::testing::prop::Gen, depth: usize) -> Json {
            match if depth > 2 { g.rng().below(4) } else { g.rng().below(6) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool(0.5)),
                2 => Json::int(g.u64() as i64 % 1_000_000),
                3 => Json::str(g.ident(12)),
                4 => Json::Arr((0..g.size(0, 4)).map(|_| gen_json(g, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..g.size(0, 4))
                        .map(|_| (g.ident(6), gen_json(g, depth + 1)))
                        .collect(),
                ),
            }
        }
        let value = gen_json(g, 0);
        let spec = memento::coordinator::task::TaskSpec {
            params: vec![("x".into(), pv_int(g.u64() as i64))],
            index: 0,
            exp: None,
        };
        let id = spec.id("prop");
        cache.put(&id, &spec, &value).map_err(|e| e.to_string())?;
        let back = cache.get(&id).ok_or("missing after put")?;
        memento::prop_assert!(back == value, "roundtrip mismatch: {back} vs {value}");
        Ok(())
    });
}

#[test]
fn prop_run_results_complete_and_deterministic_under_any_worker_count() {
    check("run-deterministic", 15, |g| {
        let n = g.size(1, 30);
        let workers = g.size(1, 8);
        let m = ConfigMatrix::builder()
            .param("i", (0..n as i64).map(pv_int).collect())
            .build()
            .unwrap();
        let run = |workers: usize| {
            Memento::new(|ctx| Ok(Json::int(ctx.param_i64("i")? * 3)))
                .workers(workers)
                .run(&m)
                .unwrap()
        };
        let a = run(workers);
        let b = run(1);
        memento::prop_assert!(a.len() == n && b.len() == n, "count");
        for (oa, ob) in a.iter().zip(b.iter()) {
            memento::prop_assert!(oa.value == ob.value, "value mismatch at {}", oa.spec.label());
            memento::prop_assert!(oa.id == ob.id, "id mismatch");
        }
        Ok(())
    });
}
