"""Layer 2 — the experiment MLP in JAX, built on the Pallas dense kernel.

One fixed-shape MLP serves every dataset in the §3 grid: features are
zero-padded to `FEATURES`, labels one-hot into `CLASSES` slots, and a
`class_mask` input marks which class slots are real (wine uses 3 of 10,
breast_cancer 2 of 10). Masked logits are driven to -1e9 before softmax, so
the padded classes receive ~zero probability and zero gradient.

The two functions AOT-exported by `aot.py`:

- ``train_step(w1, b1, w2, b2, x, y_onehot, class_mask, lr)``
    → ``(w1', b1', w2', b2', loss)`` — one SGD minibatch step;
- ``predict(w1, b1, w2, b2, x, class_mask)``
    → ``logits`` — masked logits, argmax taken on the Rust side.

Parameters are plain arrays (not a pytree) so the Rust runtime can pass
them positionally without pytree knowledge.
"""

import jax
import jax.numpy as jnp

from .kernels.dense import dense
from .kernels.softmax_xent import softmax_xent_mean

# AOT-fixed shapes shared with the Rust runtime via artifacts/manifest.json.
BATCH = 128
FEATURES = 64
HIDDEN = 32
CLASSES = 10

NEG_INF = -1.0e9


def mlp_logits(w1, b1, w2, b2, x, class_mask):
    """Forward pass: dense+ReLU → dense, masked to valid classes."""
    h = dense(x, w1, b1, "relu")
    logits = dense(h, w2, b2, "none")
    # Invalid class slots get -1e9: ~0 softmax mass, ~0 gradient.
    return logits + (1.0 - class_mask)[None, :] * NEG_INF


def loss_fn(w1, b1, w2, b2, x, y_onehot, class_mask):
    """Mean masked softmax cross-entropy (fused Pallas kernel; masked slots
    carry -1e9 logits so they contribute neither mass nor gradient —
    y_onehot is zero on invalid slots by construction)."""
    logits = mlp_logits(w1, b1, w2, b2, x, class_mask)
    return softmax_xent_mean(logits, y_onehot)


def train_step(w1, b1, w2, b2, x, y_onehot, class_mask, lr):
    """One SGD step; returns updated params and the pre-step loss."""
    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2, 3))(
        w1, b1, w2, b2, x, y_onehot, class_mask
    )
    g1, gb1, g2, gb2 = grads
    return (
        w1 - lr * g1,
        b1 - lr * gb1,
        w2 - lr * g2,
        b2 - lr * gb2,
        loss,
    )


def predict(w1, b1, w2, b2, x, class_mask):
    """Masked logits for a batch (argmax on the Rust side)."""
    return mlp_logits(w1, b1, w2, b2, x, class_mask)


def init_params(key, features=FEATURES, hidden=HIDDEN, classes=CLASSES):
    """He-initialized parameters (reference initializer; the Rust runtime
    reimplements this distribution with its own RNG)."""
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (features, hidden)) * jnp.sqrt(2.0 / features)
    b1 = jnp.zeros((hidden,))
    w2 = jax.random.normal(k2, (hidden, classes)) * jnp.sqrt(2.0 / hidden)
    b2 = jnp.zeros((classes,))
    return w1, b1, w2, b2


def example_args(batch=BATCH, features=FEATURES, hidden=HIDDEN, classes=CLASSES):
    """ShapeDtypeStructs for AOT lowering of train_step."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((features, hidden), f32),  # w1
        jax.ShapeDtypeStruct((hidden,), f32),  # b1
        jax.ShapeDtypeStruct((hidden, classes), f32),  # w2
        jax.ShapeDtypeStruct((classes,), f32),  # b2
        jax.ShapeDtypeStruct((batch, features), f32),  # x
        jax.ShapeDtypeStruct((batch, classes), f32),  # y_onehot
        jax.ShapeDtypeStruct((classes,), f32),  # class_mask
        jax.ShapeDtypeStruct((), f32),  # lr
    )


def example_predict_args(batch=BATCH, features=FEATURES, hidden=HIDDEN, classes=CLASSES):
    """ShapeDtypeStructs for AOT lowering of predict."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((features, hidden), f32),
        jax.ShapeDtypeStruct((hidden,), f32),
        jax.ShapeDtypeStruct((hidden, classes), f32),
        jax.ShapeDtypeStruct((classes,), f32),
        jax.ShapeDtypeStruct((batch, features), f32),
        jax.ShapeDtypeStruct((classes,), f32),
    )
