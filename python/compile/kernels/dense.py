"""Layer 1 — Pallas fused dense kernel.

The experiment MLP's hot-spot is the dense layer (both layers of the MLP,
forward and backward). It is written as a tiled Pallas matmul with fused
bias + optional ReLU, plus a `custom_vjp` wrapper so the backward pass runs
through the same Pallas matmul kernel (dx = g·Wᵀ, dW = xᵀ·g).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles the output
over (M/bm × N/bn) blocks; each program loads an (bm × K) strip of `x` and a
(K × bn) strip of `w` into VMEM via BlockSpec and issues one MXU-shaped
`jnp.dot` with f32 accumulation. With bm = bn = 128 and K ≤ 512, resident
VMEM is ≤ (128·512 + 512·128 + 128·128)·4 B ≈ 580 KiB ≪ 16 MiB, leaving
room for double-buffering. `interpret=True` everywhere: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is the correctness
(and AOT-lowering) path; real-TPU efficiency is estimated from the BlockSpec
in EXPERIMENTS.md §Perf-L1.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default output tile. 128 matches both the MXU systolic array edge and the
# lane width; small shapes fall back to the full dimension.
BLOCK_M = 128
BLOCK_N = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm × bn) output tile: full-K contraction on the MXU."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _bias_act_matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    """Fused tile: matmul + bias broadcast + optional ReLU, one VMEM pass."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)[None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _tile(dim, block):
    """Largest tile ≤ block that divides dim (dim is padded by callers to
    make this non-degenerate for the shapes we AOT)."""
    if dim <= block:
        return dim
    t = block
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def matmul_pallas(x, w, *, block_m=BLOCK_M, block_n=BLOCK_N):
    """Tiled Pallas matmul: (M × K) @ (K × N) → (M × N)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn = _tile(m, block_m), _tile(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


@functools.partial(jax.jit, static_argnames=("activation", "block_m", "block_n"))
def dense_fused(x, w, b, *, activation="none", block_m=BLOCK_M, block_n=BLOCK_N):
    """Fused dense forward: act(x @ w + b), one Pallas pass."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bn = _tile(m, block_m), _tile(n, block_n)
    grid = (m // bm, n // bn)
    kernel = functools.partial(_bias_act_matmul_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)


# ---------------------------------------------------------------------------
# custom_vjp dense layer: Pallas forward AND Pallas backward.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, activation="none"):
    """Differentiable fused dense layer (Pallas fwd + Pallas bwd)."""
    return dense_fused(x, w, b, activation=activation)


def _dense_fwd(x, w, b, activation):
    out = dense_fused(x, w, b, activation=activation)
    # Save `out` rather than pre-activation: for ReLU, (out > 0) is the mask.
    return out, (x, w, out)


def _dense_bwd(activation, res, g):
    x, w, out = res
    if activation == "relu":
        g = g * (out > 0).astype(g.dtype)
    # dx = g @ wᵀ ; dw = xᵀ @ g ; db = Σ_batch g — matmuls via the Pallas
    # kernel so the backward hot path exercises L1 too.
    dx = matmul_pallas(g, w.T)
    dw = matmul_pallas(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
