"""Layer 1 — fused masked softmax cross-entropy Pallas kernel.

The MLP's loss is -mean(sum(y_onehot * log_softmax(logits))). Computing it
naively materializes log-probabilities in HBM; this kernel fuses max /
exp-sum / dot into one VMEM pass per batch tile, emitting only the per-row
loss. The backward pass (softmax(logits) - y) / B is likewise one fused
Pallas pass.

Numerically stable: row max is subtracted before exponentiation. Masked
class slots arrive as -1e9 logits from the model, so they vanish from both
the normalizer (exp(-1e9 - max) == 0) and the gradient.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128


def _tile(dim, block):
    if dim <= block:
        return dim
    t = block
    while dim % t != 0:
        t -= 1
    return t


def _xent_fwd_kernel(logits_ref, y_ref, loss_ref):
    """Per-row CE loss for one batch tile (full class dim resident)."""
    logits = logits_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))
    logp = shifted - lse
    loss_ref[...] = -jnp.sum(y * logp, axis=-1).astype(loss_ref.dtype)


def _xent_bwd_kernel(logits_ref, y_ref, g_ref, dlogits_ref):
    """d/dlogits of g·mean-CE for one tile: g * (softmax - y) (scaled by
    1/B outside via g)."""
    logits = logits_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    dlogits_ref[...] = (g_ref[...].astype(jnp.float32)[:, None] * (p - y)).astype(
        dlogits_ref.dtype
    )


@jax.jit
def _per_row_loss(logits, y_onehot):
    b, c = logits.shape
    bb = _tile(b, BLOCK_B)
    return pl.pallas_call(
        _xent_fwd_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(logits, y_onehot)


@jax.jit
def _per_row_grad(logits, y_onehot, g_rows):
    b, c = logits.shape
    bb = _tile(b, BLOCK_B)
    return pl.pallas_call(
        _xent_bwd_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), logits.dtype),
        interpret=True,
    )(logits, y_onehot, g_rows)


@jax.custom_vjp
def softmax_xent_mean(logits, y_onehot):
    """Mean softmax cross-entropy over the batch (fused Pallas fwd + bwd)."""
    return jnp.mean(_per_row_loss(logits, y_onehot))


def _fwd(logits, y_onehot):
    return softmax_xent_mean(logits, y_onehot), (logits, y_onehot)


def _bwd(res, g):
    logits, y_onehot = res
    b = logits.shape[0]
    g_rows = jnp.full((b,), g / b, dtype=jnp.float32)
    return _per_row_grad(logits, y_onehot, g_rows), None


softmax_xent_mean.defvjp(_fwd, _bwd)


@functools.partial(jax.jit, static_argnames=())
def softmax_xent_mean_ref(logits, y_onehot):
    """Pure-jnp oracle (also used by tests)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))
