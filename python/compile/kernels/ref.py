"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here;
`python/tests/test_kernel.py` sweeps shapes/dtypes and asserts allclose
between the two. The references are also used by `test_model.py` to verify
the custom-VJP dense layer differentiates identically to plain jnp.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain matmul with f32 accumulation."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def dense_ref(x, w, b, activation="none"):
    """Reference dense layer: x @ w + b with optional ReLU."""
    out = jnp.matmul(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    out = out.astype(x.dtype)
    if activation == "relu":
        out = jnp.maximum(out, 0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out
