"""AOT lowering: JAX → HLO **text** artifacts for the Rust runtime.

HLO text — not serialized HloModuleProto — is the interchange format: the
image's xla_extension 0.5.1 rejects jax≥0.5 protos (64-bit instruction
ids), while the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
    mlp_train_step.hlo.txt   one SGD minibatch step
    mlp_predict.hlo.txt      masked logits
    manifest.json            shapes/dtypes/ordering for the Rust loader

Run via `make artifacts`; a no-op when outputs are newer than inputs
(handled by make). Python never runs after this step.
"""

import argparse
import hashlib
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name, shape):
    return {"name": name, "shape": list(shape), "dtype": "f32"}


def build_manifest() -> dict:
    b, f, h, c = model.BATCH, model.FEATURES, model.HIDDEN, model.CLASSES
    param_specs = [
        _spec("w1", (f, h)),
        _spec("b1", (h,)),
        _spec("w2", (h, c)),
        _spec("b2", (c,)),
    ]
    return {
        "meta": {"batch": b, "features": f, "hidden": h, "classes": c},
        "artifacts": {
            "mlp_train_step": {
                "file": "mlp_train_step.hlo.txt",
                "inputs": param_specs
                + [
                    _spec("x", (b, f)),
                    _spec("y_onehot", (b, c)),
                    _spec("class_mask", (c,)),
                    _spec("lr", ()),
                ],
                "outputs": param_specs + [_spec("loss", ())],
            },
            "mlp_predict": {
                "file": "mlp_predict.hlo.txt",
                "inputs": param_specs
                + [_spec("x", (b, f)), _spec("class_mask", (c,))],
                "outputs": [_spec("logits", (b, c))],
            },
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    jobs = {
        "mlp_train_step.hlo.txt": (model.train_step, model.example_args()),
        "mlp_predict.hlo.txt": (model.predict, model.example_predict_args()),
    }
    manifest = build_manifest()
    for fname, (fn, spec_args) in jobs.items():
        lowered = jax.jit(fn).lower(*spec_args)
        text = to_hlo_text(lowered)
        path = out_dir / fname
        path.write_text(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        print(f"wrote {path} ({len(text)} chars, sha256 {digest})")
        # record the digest so the rust runtime can detect stale artifacts
        key = fname.replace(".hlo.txt", "")
        manifest["artifacts"][key]["sha256_16"] = digest

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
