"""L1 correctness: fused softmax-CE Pallas kernel vs jnp oracle (values and
gradients), across shapes, masking, and extreme logits."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.softmax_xent import softmax_xent_mean, softmax_xent_mean_ref

SHAPES = [(1, 2), (4, 3), (16, 10), (128, 10), (130, 7), (256, 10)]


def _batch(key, b, c, n_valid=None):
    kx, ky = jax.random.split(key)
    logits = jax.random.normal(kx, (b, c), jnp.float32) * 3.0
    labels = jax.random.randint(ky, (b,), 0, n_valid or c)
    y = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    if n_valid is not None and n_valid < c:
        mask = jnp.zeros((c,), jnp.float32).at[:n_valid].set(1.0)
        logits = logits + (1.0 - mask)[None, :] * -1.0e9
    return logits, y


@pytest.mark.parametrize("b,c", SHAPES)
def test_loss_matches_ref(b, c):
    logits, y = _batch(jax.random.PRNGKey(b * 31 + c), b, c)
    got = float(softmax_xent_mean(logits, y))
    want = float(softmax_xent_mean_ref(logits, y))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,c", [(16, 10), (128, 10), (64, 5)])
def test_grad_matches_ref(b, c):
    logits, y = _batch(jax.random.PRNGKey(7 + b), b, c)
    g_got = jax.grad(lambda l: softmax_xent_mean(l, y))(logits)
    g_want = jax.grad(lambda l: softmax_xent_mean_ref(l, y))(logits)
    np.testing.assert_allclose(np.asarray(g_got), np.asarray(g_want), rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("n_valid", [2, 3, 7])
def test_masked_slots_zero_gradient(n_valid):
    b, c = 32, 10
    logits, y = _batch(jax.random.PRNGKey(n_valid), b, c, n_valid=n_valid)
    g = jax.grad(lambda l: softmax_xent_mean(l, y))(logits)
    masked = np.asarray(g)[:, n_valid:]
    assert np.abs(masked).max() < 1e-12, np.abs(masked).max()
    # and the loss equals the ref on the same masked logits
    np.testing.assert_allclose(
        float(softmax_xent_mean(logits, y)),
        float(softmax_xent_mean_ref(logits, y)),
        rtol=1e-5,
    )


def test_uniform_logits_give_log_c():
    for c in (2, 5, 10):
        logits = jnp.zeros((8, c), jnp.float32)
        y = jax.nn.one_hot(jnp.arange(8) % c, c, dtype=jnp.float32)
        assert abs(float(softmax_xent_mean(logits, y)) - np.log(c)) < 1e-6


def test_extreme_logits_stable():
    logits = jnp.array([[1000.0, -1000.0], [-1000.0, 1000.0]], jnp.float32)
    y = jnp.eye(2, dtype=jnp.float32)
    loss = float(softmax_xent_mean(logits, y))
    assert np.isfinite(loss) and loss < 1e-6
    g = jax.grad(lambda l: softmax_xent_mean(l, y))(logits)
    assert np.isfinite(np.asarray(g)).all()


def test_perfect_prediction_near_zero_loss():
    b, c = 16, 4
    labels = jnp.arange(b) % c
    y = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    logits = y * 50.0
    assert float(softmax_xent_mean(logits, y)) < 1e-6
