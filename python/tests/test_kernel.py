"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Parameterized sweeps over shapes (including non-tile-multiple and degenerate
dims), dtypes, activations, block sizes, and gradients (the custom-VJP dense
must differentiate identically to the jnp reference).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.dense import dense, dense_fused, matmul_pallas
from compile.kernels.ref import dense_ref, matmul_ref

SHAPES = [
    (1, 1, 1),
    (2, 3, 4),
    (8, 16, 4),
    (16, 8, 8),
    (32, 64, 10),
    (128, 64, 32),  # the MLP layer-1 shape
    (128, 32, 10),  # the MLP layer-2 shape
    (130, 70, 36),  # non-multiples of the tile
    (256, 128, 128),
    (1, 64, 32),  # single-row batch
]


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, dtype=jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_matches_ref(m, k, n, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 1000 + k * 10 + n))
    x = _rand(kx, (m, k), dtype)
    w = _rand(kw, (k, n), dtype)
    got = matmul_pallas(x, w)
    want = matmul_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("activation", ["none", "relu"])
def test_dense_fused_matches_ref(m, k, n, activation):
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(m + k + n), 3)
    x = _rand(kx, (m, k), jnp.float32)
    w = _rand(kw, (k, n), jnp.float32)
    b = _rand(kb, (n,), jnp.float32)
    got = dense_fused(x, w, b, activation=activation)
    want = dense_ref(x, w, b, activation=activation)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_m,block_n", [(8, 8), (32, 16), (128, 128), (256, 64)])
def test_block_size_invariance(block_m, block_n):
    """The tiling is a schedule, not semantics: results must not depend on it."""
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = _rand(kx, (64, 48), jnp.float32)
    w = _rand(kw, (48, 32), jnp.float32)
    base = matmul_pallas(x, w, block_m=128, block_n=128)
    got = matmul_pallas(x, w, block_m=block_m, block_n=block_n)
    # Different tilings change f32 accumulation order; only bit-level
    # rounding differences are acceptable.
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("activation", ["none", "relu"])
@pytest.mark.parametrize("m,k,n", [(16, 8, 4), (128, 64, 32), (32, 64, 10)])
def test_dense_gradients_match_ref(m, k, n, activation):
    """custom_vjp backward (Pallas matmuls) ≡ autodiff through the reference."""
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(42 + m), 3)
    x = _rand(kx, (m, k), jnp.float32)
    w = _rand(kw, (k, n), jnp.float32)
    b = _rand(kb, (n,), jnp.float32)

    def loss_pallas(x, w, b):
        return jnp.sum(dense(x, w, b, activation) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(dense_ref(x, w, b, activation) ** 2)

    got = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for g, r, name in zip(got, want, "x w b".split()):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(r), rtol=2e-4, atol=2e-4, err_msg=f"grad {name}"
        )


def test_relu_grad_zero_below_threshold():
    x = jnp.array([[-5.0, 5.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), jnp.float32)

    def f(x):
        return jnp.sum(dense(x, w, b, "relu"))

    g = jax.grad(f)(x)
    assert g[0, 0] == 0.0, "negative pre-activation must have zero grad"
    assert g[0, 1] == 1.0


def test_matmul_rejects_mismatched_contraction():
    x = jnp.zeros((4, 5), jnp.float32)
    w = jnp.zeros((6, 3), jnp.float32)
    with pytest.raises(AssertionError):
        matmul_pallas(x, w)


def test_jit_composability():
    """The kernel must lower inside an outer jit (the AOT path)."""

    @jax.jit
    def f(x, w, b):
        return dense(x, w, b, "relu").sum()

    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    x = _rand(kx, (16, 8), jnp.float32)
    w = _rand(kw, (8, 4), jnp.float32)
    b = jnp.zeros((4,), jnp.float32)
    expected = dense_ref(x, w, b, "relu").sum()
    np.testing.assert_allclose(float(f(x, w, b)), float(expected), rtol=1e-5)
