"""AOT pipeline: lowering produces loadable HLO text + a sound manifest."""

import json
import subprocess
import sys
import pathlib

import jax
import pytest

from compile import aot, model

REPO = pathlib.Path(__file__).resolve().parents[2]


def test_to_hlo_text_train_step():
    lowered = jax.jit(model.train_step).lower(*model.example_args())
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    # 5 outputs: 4 params + loss
    assert "f32[64,32]" in text  # w1 present
    assert "f32[128,64]" in text  # x present


def test_to_hlo_text_predict():
    lowered = jax.jit(model.predict).lower(*model.example_predict_args())
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[128,10]" in text  # logits


def test_manifest_matches_model_constants():
    m = aot.build_manifest()
    assert m["meta"]["batch"] == model.BATCH
    ts = m["artifacts"]["mlp_train_step"]
    assert [i["name"] for i in ts["inputs"]] == [
        "w1", "b1", "w2", "b2", "x", "y_onehot", "class_mask", "lr",
    ]
    assert ts["inputs"][0]["shape"] == [model.FEATURES, model.HIDDEN]
    assert ts["outputs"][-1]["name"] == "loss"
    pr = m["artifacts"]["mlp_predict"]
    assert pr["outputs"][0]["shape"] == [model.BATCH, model.CLASSES]


def test_aot_main_writes_artifacts(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path)],
        cwd=REPO / "python",
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    for f in ["mlp_train_step.hlo.txt", "mlp_predict.hlo.txt", "manifest.json"]:
        assert (tmp_path / f).exists(), f
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert "sha256_16" in manifest["artifacts"]["mlp_train_step"]
    text = (tmp_path / "mlp_train_step.hlo.txt").read_text()
    assert text.startswith("HloModule")


def test_hlo_text_round_trips_through_xla_client():
    """The text must be parseable back into an XlaComputation (what the
    rust xla crate does at load time)."""
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(model.predict).lower(*model.example_predict_args())
    text = aot.to_hlo_text(lowered)
    # xla_client exposes the HLO text parser used by HloModuleProto::from_text
    try:
        mod = xc._xla.hlo_module_from_text(text)
    except AttributeError:
        pytest.skip("hlo_module_from_text not exposed in this jaxlib")
    assert mod is not None
