"""L2 correctness: the MLP's loss decreases, masking works, shapes hold."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _toy_batch(key, n_valid_classes, batch=model.BATCH):
    """A linearly separable batch within the first `n_valid_classes` slots."""
    kx, ky = jax.random.split(key)
    y = jax.random.randint(ky, (batch,), 0, n_valid_classes)
    # class-dependent mean on the first 8 features
    means = jax.random.normal(kx, (n_valid_classes, model.FEATURES)) * 2.0
    noise = jax.random.normal(ky, (batch, model.FEATURES)) * 0.5
    x = means[y] + noise
    y_onehot = jax.nn.one_hot(y, model.CLASSES, dtype=jnp.float32)
    mask = jnp.zeros((model.CLASSES,), jnp.float32).at[:n_valid_classes].set(1.0)
    return x.astype(jnp.float32), y, y_onehot, mask


@pytest.mark.parametrize("n_valid", [2, 3, 10])
def test_train_step_decreases_loss(n_valid):
    key = jax.random.PRNGKey(n_valid)
    params = model.init_params(key)
    x, _, y_onehot, mask = _toy_batch(jax.random.PRNGKey(100 + n_valid), n_valid)
    lr = jnp.float32(0.1)

    losses = []
    w1, b1, w2, b2 = params
    for _ in range(30):
        w1, b1, w2, b2, loss = model.train_step(w1, b1, w2, b2, x, y_onehot, mask, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, f"loss did not decrease: {losses[:3]} → {losses[-3:]}"
    assert np.isfinite(losses).all()


def test_initial_loss_is_log_n_valid():
    """With ~uniform init logits, masked CE ≈ ln(n_valid), not ln(CLASSES)."""
    key = jax.random.PRNGKey(0)
    w1, b1, w2, b2 = model.init_params(key)
    # zero weights → exactly uniform over valid classes
    w1, w2 = jnp.zeros_like(w1), jnp.zeros_like(w2)
    for n_valid in (2, 3, 10):
        x, _, y_onehot, mask = _toy_batch(jax.random.PRNGKey(1), n_valid)
        loss = model.loss_fn(w1, b1, w2, b2, x, y_onehot, mask)
        assert abs(float(loss) - np.log(n_valid)) < 1e-3, (n_valid, float(loss))


def test_predict_never_picks_masked_class():
    key = jax.random.PRNGKey(3)
    params = model.init_params(key)
    x, _, _, mask = _toy_batch(jax.random.PRNGKey(4), 3)
    logits = model.predict(*params, x, mask)
    assert logits.shape == (model.BATCH, model.CLASSES)
    pred = np.asarray(jnp.argmax(logits, axis=-1))
    assert (pred < 3).all(), f"masked class predicted: {np.unique(pred)}"


def test_masked_classes_get_no_gradient():
    key = jax.random.PRNGKey(5)
    w1, b1, w2, b2 = model.init_params(key)
    x, _, y_onehot, mask = _toy_batch(jax.random.PRNGKey(6), 2)
    grads = jax.grad(model.loss_fn, argnums=(2, 3))(w1, b1, w2, b2, x, y_onehot, mask)
    g_w2, g_b2 = grads
    # output columns for masked classes (2..) must be ~0
    masked_cols = np.asarray(g_w2)[:, 2:]
    assert np.abs(masked_cols).max() < 1e-6, np.abs(masked_cols).max()
    assert np.abs(np.asarray(g_b2)[2:]).max() < 1e-6


def test_train_step_learns_to_high_accuracy():
    key = jax.random.PRNGKey(7)
    w1, b1, w2, b2 = model.init_params(key)
    x, y, y_onehot, mask = _toy_batch(jax.random.PRNGKey(8), 3)
    lr = jnp.float32(0.2)
    for _ in range(150):
        w1, b1, w2, b2, _ = model.train_step(w1, b1, w2, b2, x, y_onehot, mask, lr)
    logits = model.predict(w1, b1, w2, b2, x, mask)
    acc = float((jnp.argmax(logits, -1) == y).mean())
    assert acc > 0.95, f"train accuracy {acc}"


def test_example_args_shapes_match_model_constants():
    args = model.example_args()
    assert args[0].shape == (model.FEATURES, model.HIDDEN)
    assert args[4].shape == (model.BATCH, model.FEATURES)
    assert args[7].shape == ()
    p_args = model.example_predict_args()
    assert len(p_args) == 6
