//! Custom-data campaign: user CSV + random search + ablation slices.
//!
//! The paper claims Memento is "compatible with any type of machine-learning
//! pipeline". This example brings your *own* data (a CSV generated here to
//! stand in for one) and the beyond-grid sweep helpers:
//!
//! 1. load a CSV dataset (`ml::io`) with missing cells and string labels,
//! 2. define a 2×3×7-combination matrix over it,
//! 3. run a seeded **random subset** (random search) of the grid,
//! 4. run an **ablation slice** (imputer pinned) of the same matrix,
//! 5. compare against the full grid — all three share one result cache, so
//!    the full run re-executes only the combinations the subset missed.
//!
//! Run: `cargo run --release --example custom_data`

use memento::config::sweep;
use memento::coordinator::memento::Memento;
use memento::coordinator::results::ResultSet;
use memento::prelude::*;
use memento::util::rng::Rng;
use std::sync::Arc;

fn write_csv(path: &std::path::Path) {
    // A 300-row, 6-feature, 3-class dataset with 2% missing cells.
    let mut rng = Rng::new(2024);
    let mut text = String::from("f0,f1,f2,f3,f4,f5,species\n");
    let names = ["setosa", "versicolor", "virginica"];
    for i in 0..300 {
        let c = i % 3;
        for f in 0..6 {
            if rng.bool(0.02) {
                text.push_str("NA,");
            } else {
                let mean = (c as f64 - 1.0) * 2.0 * ((f % 3) as f64 + 0.5);
                text.push_str(&format!("{:.3},", mean + rng.normal()));
            }
        }
        text.push_str(names[c]);
        text.push('\n');
    }
    memento::util::fs::atomic_write(path, text.as_bytes()).unwrap();
}

fn main() -> Result<(), MementoError> {
    let dir = std::path::PathBuf::from("target/custom_data");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let csv_path = dir.join("species.csv");
    write_csv(&csv_path);

    let matrix = ConfigMatrix::builder()
        .param(
            "feature_engineering",
            vec![pv_str("DummyImputer"), pv_str("SimpleImputer")],
        )
        .param(
            "preprocessing",
            vec![
                pv_str("DummyPreprocessor"),
                pv_str("MinMaxScaler"),
                pv_str("StandardScaler"),
            ],
        )
        .param(
            "model",
            memento::ml::pipeline::MODEL_NAMES.iter().map(|n| pv_str(*n)).collect(),
        )
        .setting("n_fold", Json::int(3))
        .setting("csv", Json::str(csv_path.to_string_lossy()))
        .build()?;
    println!(
        "matrix: {} combinations over {} model families",
        matrix.raw_count(),
        memento::ml::pipeline::MODEL_NAMES.len()
    );

    let exp = |ctx: &TaskContext| -> Result<Json, MementoError> {
        let csv = ctx
            .setting("csv")
            .and_then(|j| j.as_str())
            .ok_or_else(|| MementoError::experiment("missing csv setting"))?;
        let ds = memento::ml::io::dataset_from_csv_file(std::path::Path::new(csv), true)
            .map_err(|e| MementoError::experiment(e.to_string()))?;
        let scores = memento::ml::pipeline::cross_validate_named(
            &ds,
            ctx.param_str("feature_engineering")?,
            ctx.param_str("preprocessing")?,
            ctx.param_str("model")?,
            ctx.setting_i64("n_fold", 3) as usize,
            &mut Rng::new(ctx.seed),
        )
        .map_err(|e| MementoError::experiment(e.to_string()))?;
        Ok(Json::obj(vec![
            ("accuracy", Json::Num(scores.mean_accuracy)),
            ("macro_f1", Json::Num(scores.mean_macro_f1)),
        ]))
    };

    let cache = Arc::new(
        memento::coordinator::cache::ResultCache::open(dir.join("cache")).unwrap(),
    );
    let runner = |label: &str, tasks: Vec<memento::coordinator::task::TaskSpec>| {
        // run_tasks via a single-use matrix isn't needed — Memento::run
        // expands matrices; for explicit task lists we use the scheduler
        // through a filtered matrix: here we emulate by running the full
        // facade on an overridden matrix when possible. For subsets, the
        // cache makes re-execution of already-done combos free anyway, so
        // we simply report what the subset *would* run.
        println!("{label}: {} tasks", tasks.len());
        tasks
    };

    // --- random search: 12 of 42 combinations ---------------------------
    let subset = sweep::random_subset(&matrix, 12, 7);
    runner("random search (seeded)", subset.clone());
    // Execute the subset by pinning: run the full matrix but with a cache —
    // first do the subset via per-task matrices.
    let m_sub = Memento::new(exp).workers(4).seed(3).with_cache(Arc::clone(&cache));
    let mut subset_outcomes = Vec::new();
    for t in &subset {
        let mini = ConfigMatrix {
            parameters: t
                .params
                .iter()
                .map(|(k, v)| (k.clone(), vec![v.clone()]))
                .collect(),
            settings: matrix.settings.clone(),
            exclude: vec![],
        };
        let r = m_sub.run(&mini)?;
        subset_outcomes.extend(r.outcomes().to_vec());
    }
    let subset_rs = ResultSet::new(subset_outcomes);
    let best = subset_rs
        .successes()
        .max_by(|a, b| a.metric("accuracy").partial_cmp(&b.metric("accuracy")).unwrap())
        .unwrap();
    println!(
        "random-search best: {} → {:.4}\n",
        best.spec.label(),
        best.metric("accuracy").unwrap()
    );

    // --- ablation slice: SimpleImputer pinned ----------------------------
    let slice = sweep::with_overrides(&matrix, &[("feature_engineering", pv_str("SimpleImputer"))])?;
    let r_slice = Memento::new(exp)
        .workers(4)
        .seed(3)
        .with_cache(Arc::clone(&cache))
        .run(&slice)?;
    println!(
        "ablation slice (SimpleImputer): {} tasks, {} from cache",
        r_slice.len(),
        r_slice.n_cached()
    );
    println!("{}", r_slice.pivot("model", "preprocessing", "accuracy").render());

    // --- full grid: cache makes the overlap free -------------------------
    let r_full = Memento::new(exp)
        .workers(4)
        .seed(3)
        .with_cache(Arc::clone(&cache))
        .run(&matrix)?;
    println!(
        "full grid: {} tasks, {} restored from cache (subset + slice overlap)",
        r_full.len(),
        r_full.n_cached()
    );
    println!("{}", r_full.pivot("model", "feature_engineering", "accuracy").render());
    println!("{}", r_full.summary());
    Ok(())
}
