//! Distributed execution over loopback TCP: a supervisor plus two
//! in-process "remote" workers, one of which keeps dropping its
//! connection mid-run and re-registering.
//!
//! In production the pieces live in different processes (or machines):
//! the supervisor runs `memento run --isolation remote --listen …
//! --token-file …` and each worker box runs `memento serve --connect …
//! --token-file …`. The protocol doesn't care where the peers live,
//! though — `serve_remote` is an ordinary function — so this example
//! runs both workers as plain threads against a loopback TCP pool, which
//! makes the whole distributed story observable in one terminal:
//!
//! 1. a [`WorkerPool`] listens on `127.0.0.1:<os-assigned>` with a
//!    shared auth token;
//! 2. two workers register (wrong-token workers would be rejected at the
//!    `Ready` handshake — try changing `TOKEN` below for one of them);
//! 3. worker A is configured with `tasks_per_connection: 3`, so it
//!    **drops its connection mid-run** after every third task, announces
//!    the departure with a `Goodbye` frame, reconnects, and re-registers
//!    — the supervisor re-queues any crossed dispatch without burning a
//!    retry attempt or crash budget, and the run completes exactly-once;
//! 4. after the run, the pool's registration counter shows how many
//!    times workers (re)joined.
//!
//! Run with: `cargo run --release --example remote_workers`

#[cfg(unix)]
use memento::ipc::pool::{PoolOptions, WorkerPool};
#[cfg(unix)]
use memento::ipc::transport::Transport;
#[cfg(unix)]
use memento::ipc::worker::{serve_remote, RemoteWorkerOptions};
use memento::prelude::*;
use std::sync::Arc;
use std::time::Duration;

#[cfg(unix)]
const TOKEN: &str = "example-shared-token";

fn exp(ctx: &TaskContext) -> Result<Json, MementoError> {
    let i = ctx.param_i64("i")?;
    // A little work, so both workers participate and the rolling
    // reconnects land mid-run rather than after it.
    std::thread::sleep(Duration::from_millis(20));
    Ok(Json::obj(vec![("square", Json::int(i * i))]))
}

#[cfg(not(unix))]
fn main() {
    // Silence unused warnings on non-unix; the distributed tier needs
    // unix (see `memento::ipc`).
    let _ = exp;
    let _: Option<Arc<()>> = None;
    eprintln!("the remote_workers example needs a unix platform");
}

#[cfg(unix)]
fn main() -> Result<(), MementoError> {
    // 1. The supervisor side: a standing pool listening on loopback TCP.
    let pool = WorkerPool::listen(
        &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
        PoolOptions { token: Some(TOKEN.to_string()), ..PoolOptions::default() },
    )?;
    let endpoint = pool.endpoint().clone();
    println!("supervisor: listening for workers on {endpoint}");

    // 2. Two "remote" workers. `give_up_after` lets them exit cleanly
    //    once the pool is gone at the end of the example.
    let worker = |name: &'static str, tasks_per_connection: Option<usize>| {
        let endpoint = endpoint.clone();
        let exp_fn: Arc<memento::coordinator::memento::ExpFn> = Arc::new(exp);
        std::thread::spawn(move || {
            let report = serve_remote(
                exp_fn,
                &endpoint,
                RemoteWorkerOptions {
                    token: Some(TOKEN.to_string()),
                    tasks_per_connection,
                    give_up_after: Some(Duration::from_millis(750)),
                    quiet: true,
                    ..RemoteWorkerOptions::default()
                },
            )
            .expect("worker must not be rejected");
            println!(
                "worker {name}: served {} task(s) over {} connection(s){}",
                report.tasks,
                report.connections,
                if report.connections > 1 { " — dropped and re-registered mid-run" } else { "" },
            );
            report
        })
    };
    // Worker A drops its connection after every 3rd task; worker B is a
    // plain standing worker.
    let a = worker("A (rolling)", Some(3));
    let b = worker("B (steady) ", None);

    // 3. The run: ordinary Memento API, remote backend, leasing from the
    //    standing pool.
    let matrix = ConfigMatrix::builder()
        .param("i", (0..12).map(pv_int).collect())
        .build()?;
    let results = Memento::new(exp)
        .with_worker_pool(Arc::clone(&pool))
        .remote_workers("<pool owns the listener>", 2)
        .run(&matrix)?;

    println!("\n{} tasks, {} failed", results.len(), results.n_failed());
    for o in results.iter() {
        println!(
            "  i={:<2} square={:<3} attempts={}",
            o.spec.get("i").unwrap(),
            o.value.as_ref().and_then(|v| v.get("square")).unwrap(),
            o.attempts,
        );
    }
    assert_eq!(results.n_failed(), 0, "reconnect churn must not cost any result");
    assert_eq!(results.len(), 12);

    // 4. Shut the pool down; the workers' reconnect loops give up and
    //    their threads end.
    let registrations = pool.registered_count();
    pool.shutdown();
    let (ra, rb) = (a.join().unwrap(), b.join().unwrap());
    println!(
        "\npool saw {registrations} registrations for 2 workers \
         (worker A re-registered {} time(s) mid-run)",
        ra.connections.saturating_sub(1),
    );
    assert_eq!(ra.tasks + rb.tasks, 12, "every task ran on some worker");
    println!("worker dropped mid-run; the run did not notice.");
    Ok(())
}
