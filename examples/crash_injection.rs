//! Crash injection: process isolation surviving worker death.
//!
//! One task in this sweep calls `std::process::abort()` on its first
//! attempt — an uncatchable, non-unwinding death, the same failure shape
//! as a segfault or an OOM kill. Under the default thread backend that
//! would take the whole run down; under `ExecBackend::Processes` only the
//! worker process dies: the supervisor journals the crash, requeues the
//! task under the retry policy, respawns the worker, and the run
//! completes with every result intact.
//!
//! Note there is no worker-specific code here. The supervisor re-executes
//! this binary with the worker environment set; when the re-execution
//! reaches `Memento::run`, it notices that environment and serves tasks
//! over the socket instead of starting a run of its own.
//!
//! Run with: `cargo run --release --example crash_injection`

use memento::prelude::*;
use std::time::Duration;

fn main() -> Result<(), MementoError> {
    let matrix = ConfigMatrix::builder()
        .param("i", (0..8).map(pv_int).collect())
        .build()?;

    let m = Memento::new(|ctx| {
        let i = ctx.param_i64("i")?;
        if i == 3 && ctx.attempt == 1 {
            eprintln!("task i=3 (pid {}): aborting the worker process!", std::process::id());
            std::process::abort();
        }
        Ok(Json::obj(vec![("square", Json::int(i * i))]))
    })
    // 2 worker processes; a crashed slot may respawn up to 3 times.
    .isolate_processes(2, 3)
    // The crash consumes one attempt, so allow a second.
    .with_retry(RetryPolicy::fixed(2, Duration::ZERO));

    let results = m.run(&matrix)?;

    println!("\n{} tasks, {} failed", results.len(), results.n_failed());
    for o in results.iter() {
        println!(
            "  i={:<2} square={:<3} attempts={}",
            o.spec.get("i").unwrap(),
            o.value.as_ref().and_then(|v| v.get("square")).unwrap(),
            o.attempts,
        );
    }
    assert_eq!(results.n_failed(), 0, "the crash must not cost any result");
    let victim = results.find(&[("i", pv_int(3))]).unwrap();
    assert_eq!(victim.attempts, 2, "i=3 survived via a second attempt");
    println!("\nworker died mid-task; the run did not.");
    Ok(())
}
