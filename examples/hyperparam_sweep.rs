//! Hyperparameter sweep over the AOT MLP (heavy L2/L1 exercise).
//!
//! A 3×3×2 = 18-task sweep of (learning rate × epochs × dataset) where
//! every task trains the PJRT-backed MLP — the Pallas dense kernel runs on
//! every forward and backward step of every task, from multiple Memento
//! workers concurrently. Reports the best configuration per dataset and
//! train-step throughput.
//!
//! Run: `make artifacts && cargo run --release --example hyperparam_sweep`

use memento::coordinator::memento::Memento;
use memento::ml::impute::{SimpleImputer, Transformer};
use memento::ml::metrics::accuracy;
use memento::ml::scale::StandardScaler;
use memento::ml::split::train_test_indices;
use memento::prelude::*;
use memento::runtime::artifact::shared_store;
use memento::runtime::mlp::{MlpModel, MlpParams};
use memento::util::rng::Rng;
use std::sync::Arc;

fn main() -> Result<(), MementoError> {
    let store = shared_store().map_err(|e| {
        MementoError::runtime(format!("{e}\nhint: run `make artifacts` first"))
    })?;
    println!(
        "artifacts: {:?} (batch={}, features={}, hidden={}, classes={})",
        store.names(),
        store.meta.batch,
        store.meta.features,
        store.meta.hidden,
        store.meta.classes
    );

    let matrix = ConfigMatrix::builder()
        .param("lr", vec![pv_f64(0.02), pv_f64(0.1), pv_f64(0.3)])
        .param("epochs", vec![pv_int(10), pv_int(25), pv_int(50)])
        .param("dataset", vec![pv_str("wine"), pv_str("breast_cancer")])
        .setting("test_frac", Json::Num(0.3))
        .build()?;

    let exp_store = Arc::clone(&store);
    let exp = move |ctx: &TaskContext| -> Result<Json, MementoError> {
        let mut ds = memento::ml::dataset::load_by_name(ctx.param_str("dataset")?, 0)
            .ok_or_else(|| MementoError::experiment("unknown dataset"))?;
        SimpleImputer::default().fit_transform(&mut ds);
        StandardScaler::default().fit_transform(&mut ds);

        let mut rng = Rng::new(ctx.seed);
        let test_frac = ctx.setting_f64("test_frac", 0.3);
        let (tr, te) = train_test_indices(&ds, test_frac, &mut rng);
        let train = ds.subset(&tr);
        let test = ds.subset(&te);

        let params = MlpParams {
            epochs: ctx.param_i64("epochs")? as usize,
            lr: ctx.param_f64("lr")? as f32,
        };
        let epochs = params.epochs;
        let mut mlp = MlpModel::new(Arc::clone(&exp_store), params);
        let t0 = std::time::Instant::now();
        let history = mlp.fit_with_history(&train, &mut rng)?;
        let train_secs = t0.elapsed().as_secs_f64();
        let steps = epochs * train.n_rows.div_ceil(exp_store.meta.batch);
        let preds = mlp.try_predict(&test)?;

        Ok(Json::obj(vec![
            ("accuracy", Json::Num(accuracy(&test.y, &preds))),
            ("final_loss", Json::Num(history.last().copied().unwrap_or(f32::NAN) as f64)),
            ("first_loss", Json::Num(history.first().copied().unwrap_or(f32::NAN) as f64)),
            ("steps_per_sec", Json::Num(steps as f64 / train_secs.max(1e-9))),
        ]))
    };

    let m = Memento::new(exp)
        .workers(4)
        .seed(11)
        .with_cache_dir("target/hyperparam_sweep/cache")
        .with_notifier(Box::new(ConsoleNotificationProvider));
    let results = m.run(&matrix)?;

    println!("\n=== accuracy by (lr × epochs), wine ===");
    let wine: Vec<_> = results.filter(&[("dataset", pv_str("wine"))]);
    print_grid(&wine);
    println!("\n=== accuracy by (lr × epochs), breast_cancer ===");
    let bc: Vec<_> = results.filter(&[("dataset", pv_str("breast_cancer"))]);
    print_grid(&bc);

    for ds_name in ["wine", "breast_cancer"] {
        let best = results
            .filter(&[("dataset", pv_str(ds_name))])
            .into_iter()
            .filter(|o| o.succeeded())
            .max_by(|a, b| {
                a.metric("accuracy")
                    .partial_cmp(&b.metric("accuracy"))
                    .unwrap()
            });
        if let Some(best) = best {
            println!(
                "best {ds_name}: {} → accuracy {:.4}",
                best.spec.label(),
                best.metric("accuracy").unwrap()
            );
        }
    }
    let mean_throughput: f64 = results
        .successes()
        .filter_map(|o| o.metric("steps_per_sec"))
        .sum::<f64>()
        / results.successes().count().max(1) as f64;
    println!("\nmean PJRT train-step throughput per task: {mean_throughput:.0} steps/s");
    println!("{}", results.summary());
    Ok(())
}

fn print_grid(outcomes: &[&memento::coordinator::results::TaskOutcome]) {
    let mut rows: Vec<(f64, i64, f64)> = outcomes
        .iter()
        .filter_map(|o| {
            Some((
                o.spec.get("lr")?.as_f64()?,
                o.spec.get("epochs")?.as_i64()?,
                o.metric("accuracy")?,
            ))
        })
        .collect();
    rows.sort_by(|a, b| (a.0, a.1).partial_cmp(&(b.0, b.1)).unwrap());
    println!("{:>6} {:>7} {:>9}", "lr", "epochs", "accuracy");
    for (lr, ep, acc) in rows {
        println!("{lr:>6} {ep:>7} {acc:>9.4}");
    }
}
