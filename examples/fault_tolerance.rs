//! Fault-tolerance demo (E4/E5): failure injection, error tracing, and
//! checkpoint resume — the paper's reliability story.
//!
//! Phase 1: run a 24-task grid where ~1/3 of tasks fail (simulating OOMs,
//!          bad hyperparameters, flaky I/O). Memento isolates each failure,
//!          records it in the checkpoint manifest, and finishes the rest.
//! Phase 2: "fix the bug" (the failure injection is keyed to the attempt
//!          count) and `resume()` the same run directory: only the failed
//!          tasks re-execute.
//! Phase 3: a retry policy handles transient failures inside a single run.
//!
//! Run: `cargo run --release --example fault_tolerance`

use memento::coordinator::retry::RetryPolicy;
use memento::prelude::*;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn matrix() -> ConfigMatrix {
    ConfigMatrix::builder()
        .param(
            "lr",
            vec![pv_f64(0.001), pv_f64(0.01), pv_f64(0.1), pv_f64(1.0)],
        )
        .param("depth", vec![pv_int(2), pv_int(4), pv_int(8)])
        .param("batch", vec![pv_int(32), pv_int(64)])
        .build()
        .expect("valid matrix")
}

fn main() -> Result<(), MementoError> {
    let run_dir = "target/fault_tolerance/run";
    let _ = std::fs::remove_dir_all("target/fault_tolerance");

    // ---------------- Phase 1: buggy experiment function ----------------
    println!("=== phase 1: buggy code — lr=1.0 diverges, depth=8 panics ===");
    let executions = Arc::new(AtomicUsize::new(0));
    let ex1 = Arc::clone(&executions);
    let buggy = move |ctx: &TaskContext| -> Result<Json, MementoError> {
        ex1.fetch_add(1, Ordering::SeqCst);
        let lr = ctx.param_f64("lr")?;
        let depth = ctx.param_i64("depth")?;
        if lr >= 1.0 {
            return Err(MementoError::experiment(format!("loss diverged at lr={lr}")));
        }
        if depth == 8 {
            panic!("simulated OOM at depth={depth}");
        }
        Ok(Json::obj(vec![(
            "score",
            Json::Num(1.0 - lr - depth as f64 * 0.01),
        )]))
    };
    let results = Memento::new(buggy)
        .workers(4)
        .with_checkpoint_dir(run_dir)
        .with_notifier(Box::new(ConsoleNotificationProvider))
        .run(&matrix())?;
    let failed_phase1 = results.n_failed();
    println!(
        "\nphase 1 done: {} (executions: {})",
        results.summary(),
        executions.load(Ordering::SeqCst)
    );
    // 4 lr × 3 depth × 2 batch = 24; lr=1.0 → 6 fail; depth=8 ∧ lr<1 → 6 panic.
    assert_eq!(results.len(), 24);
    assert_eq!(failed_phase1, 12);

    // ---------------- Phase 2: fixed code + resume ----------------------
    println!("\n=== phase 2: code fixed — resume re-runs ONLY the 12 failures ===");
    let executions2 = Arc::new(AtomicUsize::new(0));
    let ex2 = Arc::clone(&executions2);
    let fixed = move |ctx: &TaskContext| -> Result<Json, MementoError> {
        ex2.fetch_add(1, Ordering::SeqCst);
        let lr = ctx.param_f64("lr")?;
        let depth = ctx.param_i64("depth")?;
        Ok(Json::obj(vec![(
            "score",
            Json::Num((1.0 - lr).max(0.0) - depth as f64 * 0.01),
        )]))
    };
    let results = Memento::new(fixed)
        .workers(4)
        .with_checkpoint_dir(run_dir)
        .with_notifier(Box::new(ConsoleNotificationProvider))
        .resume(&matrix())?;
    let reran = executions2.load(Ordering::SeqCst);
    println!(
        "\nphase 2 done: {} — re-executed {reran}/24 tasks (the rest restored)",
        results.summary()
    );
    assert_eq!(results.n_failed(), 0);
    assert_eq!(reran, 12, "resume must re-run exactly the failures");
    assert_eq!(results.n_cached(), 12);

    // ---------------- Phase 3: transient failures + retry ----------------
    println!("\n=== phase 3: transient faults absorbed by RetryPolicy ===");
    let flaky = |ctx: &TaskContext| -> Result<Json, MementoError> {
        // Fails twice, succeeds on the 3rd attempt — a network hiccup.
        if ctx.attempt < 3 {
            Err(MementoError::experiment("connection reset by peer"))
        } else {
            Ok(Json::int(ctx.attempt as i64))
        }
    };
    let results = Memento::new(flaky)
        .workers(4)
        .with_retry(RetryPolicy::exponential(
            3,
            Duration::from_millis(1),
            2.0,
            Duration::from_millis(10),
        ))
        .run(&matrix())?;
    println!("phase 3 done: {}", results.summary());
    assert_eq!(results.n_failed(), 0);
    assert!(results.iter().all(|o| o.attempts == 3));

    println!("\nfault-tolerance demo complete: 12/24 failures isolated, resume re-ran only failures, retries absorbed transients.");
    Ok(())
}
