//! End-to-end driver (E7): the full §3 grid, extended with the AOT MLP.
//!
//! Exercises all three layers on a real workload:
//!   L3 — the Memento coordinator expands 3 datasets × 2 imputers × 3
//!        preprocessors × 4 models = 72 combinations − 12 excluded = 60
//!        tasks and runs them across all cores with caching, checkpointing,
//!        and notifications;
//!   L2 — the `MLP` model family executes the JAX-lowered `mlp_train_step`
//!        / `mlp_predict` HLO through PJRT;
//!   L1 — those artifacts contain the Pallas fused-dense kernel on both the
//!        forward and backward paths.
//!
//! Prints the per-(dataset × model) accuracy grid, wallclock, and the
//! sequential-vs-parallel comparison recorded in EXPERIMENTS.md E7.
//!
//! Run: `make artifacts && cargo run --release --example ml_grid`
//! Flags: --workers N, --skip-mlp, --quick (3-fold, fewer tasks)

use memento::coordinator::notify::ConsoleNotificationProvider;
use memento::coordinator::memento::Memento;
use memento::experiments::grid;
use memento::runtime::artifact::shared_store;
use memento::util::cli::CliSpec;
use memento::util::time::Stopwatch;
use std::time::Duration;

fn main() {
    let spec = CliSpec::new("ml_grid", "the §3 demonstration grid, end to end")
        .opt("workers", "0", "worker threads (0 = all cores)")
        .flag("skip-mlp", "run the 45-task paper grid without the AOT MLP")
        .flag("quick", "toy-dataset variant (fast smoke run)");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let a = match spec.parse(&args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };

    let (matrix, store) = if a.flag("quick") {
        (grid::toy_matrix(), None)
    } else if a.flag("skip-mlp") {
        (grid::paper_matrix(), None)
    } else {
        match shared_store() {
            Ok(s) => (grid::extended_matrix(), Some(s)),
            Err(e) => {
                eprintln!("cannot open artifacts ({e}); falling back to --skip-mlp");
                (grid::paper_matrix(), None)
            }
        }
    };

    let raw = matrix.raw_count();
    let tasks = memento::coordinator::expand::count_included(&matrix);
    println!("config matrix: {raw} raw combinations, {} excluded, {tasks} tasks", raw - tasks);

    let workers = match a.get_usize("workers") {
        Ok(0) | Err(_) => memento::util::pool::num_cpus(),
        Ok(n) => n,
    };
    println!("workers: {workers}\n");

    let m = Memento::new(grid::grid_exp_fn(store))
        .workers(workers)
        .seed(0)
        .with_cache_dir("target/ml_grid/cache")
        .with_checkpoint_dir("target/ml_grid/run")
        .with_notifier(Box::new(ConsoleNotificationProvider))
        .progress_every(Duration::from_secs(2));
    let metrics = m.metrics();

    let sw = Stopwatch::start();
    let results = match m.run(&matrix) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("run failed: {e}");
            std::process::exit(1);
        }
    };
    let wall = sw.elapsed_secs();

    println!("\n=== E7: accuracy grid (mean over 5-fold CV) ===");
    println!("{}", results.pivot("dataset", "model", "accuracy").render());
    println!("=== macro-F1 ===");
    println!("{}", results.pivot("dataset", "model", "macro_f1").render());

    for f in results.failures() {
        if let Some(fail) = &f.failure {
            println!("FAILED: {}", fail.summary());
        }
    }

    let exec_total: f64 = results.iter().map(|o| o.duration_secs).sum();
    println!("{}", results.summary());
    print!("{}", metrics.render(wall));
    println!(
        "\nparallel efficiency: cumulative exec {:.1}s / (wall {:.1}s × {workers} workers) = {:.0}%",
        exec_total,
        wall,
        100.0 * exec_total / (wall * workers as f64)
    );
    println!("(re-run this binary to see the warm-cache path: all tasks restore instantly)");
}
