//! Quickstart: the paper's §3 workflow in ~40 lines.
//!
//! 1. define a configuration matrix,
//! 2. define an experiment function,
//! 3. `Memento::new(exp_func).run(&matrix)` — parallel execution, caching,
//!    and notifications included.
//!
//! Run: `cargo run --release --example quickstart`

use memento::prelude::*;

fn main() -> Result<(), MementoError> {
    // 1. The configuration matrix: 2 × 3 = 6 experiments, one excluded.
    let matrix = ConfigMatrix::builder()
        .param("dataset", vec![pv_str("toy"), pv_str("wine")])
        .param(
            "model",
            vec![pv_str("SVC"), pv_str("RandomForest"), pv_str("AdaBoost")],
        )
        .setting("n_fold", Json::int(3))
        .exclude(vec![("dataset", pv_str("wine")), ("model", pv_str("AdaBoost"))])
        .build()?;

    // 2. The experiment function: k-fold CV of a named model on a dataset.
    let exp_func = |ctx: &TaskContext| -> Result<Json, MementoError> {
        let dataset = memento::ml::dataset::load_by_name(ctx.param_str("dataset")?, 0)
            .ok_or_else(|| MementoError::experiment("unknown dataset"))?;
        let scores = memento::ml::pipeline::cross_validate_named(
            &dataset,
            "SimpleImputer",
            "StandardScaler",
            ctx.param_str("model")?,
            ctx.setting_i64("n_fold", 3) as usize,
            &mut memento::util::rng::Rng::new(ctx.seed),
        )
        .map_err(|e| MementoError::experiment(e.to_string()))?;
        Ok(Json::obj(vec![("accuracy", Json::Num(scores.mean_accuracy))]))
    };

    // 3. Run it: parallel, cached, with console notifications.
    let results = Memento::new(exp_func)
        .workers(4)
        .with_cache_dir("target/quickstart-cache")
        .with_notifier(Box::new(ConsoleNotificationProvider))
        .run(&matrix)?;

    println!("\n{}", results.pivot("dataset", "model", "accuracy").render());
    println!("{}", results.summary());
    Ok(())
}
